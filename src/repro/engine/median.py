"""One MEDIAN/k-party turn as a pure jitted ``step(state) -> state``.

Faithful vectorization of the certified-pivot epoch protocol that used to
live as a host-side Python loop in ``repro.core.protocols.kparty`` (paper
§5/§6.2, certified-pivot variant per DESIGN.md).  A whole batch of B
instances advances in lock-step under ``lax.while_loop``; finished instances
are masked no-ops until every instance terminates or the turn budget runs
out.  The single-instance public API is exactly this engine with B=1, so
batched-vs-sequential parity is structural, not approximate.

Turn structure (coordinator ci = turn % k, per-instance — the turn counter
is a (B,) leaf, so a dispatch may mix sessions at different phases; a plain
sweep keeps every row in lock-step):

1. coordinator ranges over its transcript → per-direction (lo, hi);
2. at-risk matrix over its own shard, full-scan weighted-median direction v;
3. broadcast its ≤2 band points S + (v, lo_c, hi_c) [k-1 point msgs + k-1
   4-scalar msgs]; S is appended to every node's transcript;
4. ε-early-exit: if the coordinator band is non-empty, every non-coordinator
   reports its error count on the band-midpoint classifier [k-1 1-scalar
   msgs]; terminate if the global count is within budget;
5. every node's extreme band points along v over own ∪ transcript;
   non-coordinators ship theirs [≤2-point msgs, skipped when empty] — each
   reply lands in the sender's and the coordinator's transcripts;
6. non-empty global band → accept bits [k-1] and terminate at the midpoint;
   empty band → the violating pair (p*, q*) certifies v·(q*-p*) > 0 for
   every consistent direction: broadcast the pair [k-1 2-point msgs, all
   transcripts] and prune the direction arc (the current v is always
   discarded — certified by the empty band, and enforced explicitly so f32
   rounding can never stall the loop).

Hot path (DESIGN.md §shared hot loop): ``run_hot`` — the ``run_instances``
default — drives the same ``step`` from the host on the selector-generic
machinery in :mod:`repro.engine.hotloop`, capping every per-turn transcript
read at the live fill (``trans_width``) and dropping finished instances
from the dispatch.  MEDIAN transcripts are mostly empty early, and the
capped reads drop only label-0 mask identities, so the hot path is
*bit-exact* against the cold padded ``run_compiled`` model (kept as
``run_instances(compact=False)``; gated in tests/test_median_hot.py).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.engine import hotloop
from repro.engine.state import (
    BatchCommLog,
    EngineData,
    ProtocolInstance,
    ProtocolState,
    device_put_sharded,
    pack_instances,
    shard_specs,
)

_INF = jnp.inf

# MEDIAN's per-turn append bound on any single transcript *before* the
# stage-5 extremes read: the broadcast S block (≤ 2 rows).  The hot loop's
# width compaction must cover the turn-start fill plus this slack, because
# the extremes scan reads the post-S transcripts.
WIDTH_SLACK = 2


def _proj_grid(V: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """(m, d) × (B, n, d) -> (B, m, n) direction projections.

    Spelled as a broadcast multiply-add: XLA:CPU lowers the K=d (=2) dot
    through a generic GEMM path that is ~5× slower than the fused
    elementwise form, and this is the engine's dominant per-turn tensor.
    """
    d = V.shape[1]
    return sum(V[None, :, i, None] * X[:, None, :, i] for i in range(d))


def _proj_dir(X: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """(B, ..., d) × (B, d) -> (B, ...): per-instance projections onto v."""
    d = X.shape[-1]
    vb = v.reshape(v.shape[0:1] + (1,) * (X.ndim - 2) + (d,))
    return sum(X[..., i] * vb[..., i] for i in range(d))


_gather_rows = hotloop.gather_rows           # (B, N, ...) × (B,) -> (B, ...)


def _gather_rows2(arr: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """arr (B, k, N, ...), idx (B, k) -> (B, k, ...)."""
    return jax.vmap(jax.vmap(lambda a, i: a[i]))(arr, idx)


def _append2(wx, wy, fill, lo_j, hi_j, pts, labs, do, V):
    """Append a ≤2-row block to each instance's transcript at its fill.

    ``pts`` (B, 2, d), ``labs`` (B, 2) with label-0 marking invalid rows
    (valid rows must be compacted to the front), ``do`` (B,) gating the
    append.  Writes always land at ≥ fill, so masked-out appends only touch
    label-0 scratch rows that the next valid append overwrites — the
    "rows ≥ fill are label-0" invariant holds by induction.

    The node's consistent-threshold ranges (lo_j, hi_j) over its transcript
    are running max/mins, so they update incrementally here — O(B·m·2) per
    append instead of an O(B·m·cap) rescan per turn; masked/label-0 rows
    contribute ∓inf, i.e. nothing.  ``repro.kernels`` ``threshold_ranges``
    over the final buffer yields the identical values (tested).
    """
    labs = jnp.where(do[:, None], labs, 0).astype(jnp.int32)
    nvalid = jnp.sum(labs != 0, axis=1).astype(jnp.int32)

    pv = jnp.swapaxes(_proj_grid(V, pts), 1, 2)          # (B, 2, m)
    lo_j = jnp.maximum(lo_j, jnp.max(
        jnp.where((labs == 1)[:, :, None], pv, -_INF), axis=1))
    hi_j = jnp.minimum(hi_j, jnp.min(
        jnp.where((labs == -1)[:, :, None], pv, _INF), axis=1))

    def upd(w, wl, f, p, l):
        return (lax.dynamic_update_slice(w, p, (f, 0)),
                lax.dynamic_update_slice(wl, l, (f,)))

    wx, wy = jax.vmap(upd)(wx, wy, fill, pts.astype(wx.dtype), labs)
    return wx, wy, fill + nvalid, lo_j, hi_j


def _extremes(XW, yW, v):
    """Per-node extreme band points along v over own ∪ transcript.

    XW (B, k, N, d), yW (B, k, N), v (B, d) ->
    (has_p, lo_k, p_k, has_q, hi_k, q_k) with shapes (B,k)/(B,k)/(B,k,d).
    """
    pj = _proj_dir(XW, v)
    posm = yW == 1
    negm = yW == -1
    has_p = jnp.any(posm, axis=2)
    has_q = jnp.any(negm, axis=2)
    pj_pos = jnp.where(posm, pj, -_INF)
    pj_neg = jnp.where(negm, pj, _INF)
    i_p = jnp.argmax(pj_pos, axis=2)
    i_q = jnp.argmin(pj_neg, axis=2)
    lo_k = jnp.where(has_p, jnp.max(pj_pos, axis=2), -_INF)
    hi_k = jnp.where(has_q, jnp.min(pj_neg, axis=2), _INF)
    p_k = _gather_rows2(XW, i_p)
    q_k = _gather_rows2(XW, i_q)
    return has_p, lo_k, p_k, has_q, hi_k, q_k


def step(
    data: EngineData,
    V: jnp.ndarray,
    state: ProtocolState,
    *,
    k: int,
    first_turn: bool = False,
    cut_kernel: bool = False,
    extremes_kernel: bool = False,
    trans_width: Optional[int] = None,
) -> ProtocolState:
    """Advance every active instance by one protocol turn (pure, jittable,
    shape-stable — usable under jit/vmap/while_loop).

    ``trans_width`` (static) caps every per-turn transcript *read* — the
    coordinator band scan and the stage-5 extremes scan — at the first
    ``trans_width`` rows; appends still write the full-capacity buffers.
    Sound whenever it covers every active instance's live fill plus the
    ≤ ``WIDTH_SLACK`` rows the S broadcast appends before the stage-5
    extremes read (``run_hot`` guarantees this; ``None`` reads the full
    capacity).  Rows at or beyond the fill are label-0 and contribute only
    mask identities to the band/extremes max-min reductions, so the cap is
    *bit-exact*, not merely decision-exact.

    ``extremes_kernel`` (static; TPU default via ``run_instances``, like
    ``cut_kernel``) routes the stage-5 per-node extremes scan through the
    fused fill-capped Pallas kernel
    (:func:`repro.kernels.support_margin.median_extremes_batched`) instead
    of the inline reduction — integer row choices, bit-for-bit against its
    jnp reference; the same FMA-boundary tie caveat as ``cut_kernel``
    applies against the *inline* path.

    ``first_turn=True`` constant-folds the (B, m, n) median-cut scan: on the
    fresh state every direction is allowed and the transcript is empty, so
    every real point is at risk at every direction, every cut scores 0, and
    the first-max pick is provably index 0 — the same value the full scan
    computes (tested), at none of its cost.

    ``cut_kernel=True`` (static; the TPU default via ``run_instances``)
    routes the median-cut scan through the fused Pallas kernel
    (:mod:`repro.kernels.median_cut`) instead of the inline histogram
    pipeline — no (B, m, n) intermediate in HBM.  The kernel is bit-for-bit
    against its jnp reference (tested); against the *inline* path it can
    pick a different — equally allowed — cut at FMA boundary ties, because
    inline projections are broadcast multiply-adds while the kernel
    contracts on the MXU (a shipped support point's own projection defines
    the band edge its strict ``>`` risk test compares against).  Within a
    backend the path is fixed, so B=1-vs-batch parity is unaffected.  The
    inline path stays the CPU default: XLA:CPU fuses it well and
    interpret-mode Pallas inside a hot loop is pathologically slow.
    """
    B, m = state.dir_ok.shape
    ci = state.turn % k                                  # (B,) per-instance
    active = ~state.done
    comm = state.comm

    # -- 1. coordinator's consistent-threshold ranges over its transcript ---
    # maintained incrementally at append time (see _append2); identical to a
    # threshold_ranges rescan of the coordinator's buffer
    Wxc = _gather_rows(state.wx, ci)                     # (B, cap, d)
    Wyc = _gather_rows(state.wy, ci)                     # (B, cap)
    if trans_width is not None:                          # fill-capped read
        Wxc = Wxc[:, :trans_width]
        Wyc = Wyc[:, :trans_width]
    lo = _gather_rows(state.lo_w, ci)                    # (B, m)
    hi = _gather_rows(state.hi_w, ci)

    # -- 2. at-risk matrix + full-scan weighted-median direction ------------
    Xc = _gather_rows(data.X, ci)                        # (B, n, d)
    yc = _gather_rows(data.y, ci)                        # (B, n)
    if first_turn:
        v_idx = jnp.zeros((B,), jnp.int32)
    elif cut_kernel:
        from repro.engine import dataplane
        score = dataplane.median_cut(V, state.dir_ok, lo, hi, Xc, yc,
                                     use_pallas=True)
        v_idx = jnp.argmax(score, axis=1)                # (B,) first max
    else:
        projc = _proj_grid(V, Xc)                        # (B, m, n)
        nonempty = (lo < hi) & state.dir_ok              # (B, m)
        # folding the row mask into the bounds (±inf ⇒ comparison always
        # false) keeps the (B, m, n) risk pipeline to one fused select pass
        lo_r = jnp.where(nonempty, lo, _INF)
        hi_r = jnp.where(nonempty, hi, -_INF)
        risk = jnp.where((yc == 1)[:, None, :],
                         projc > lo_r[:, :, None], projc < hi_r[:, :, None])
        # For every allowed cut angle, count points whose whole risk arc
        # lies strictly on each side; maximize the smaller count (the
        # discretized weighted-median hull edge, full scan over all allowed
        # cuts).  A point's arc is entirely ≤ cut i iff its last risk row is
        # ≤ i, entirely > i iff its first risk row is > i — histograms of
        # first/last indices give every cut's counts without materializing
        # the (B, m, n) running cumsum.
        idx = jnp.arange(m)[None, :, None]
        last = jnp.max(jnp.where(risk, idx, -1), axis=1)     # (B, n)
        first = jnp.min(jnp.where(risk, idx, m), axis=1)     # (B, n)
        rows = jnp.arange(B)[:, None]
        livei = ((last >= 0) & (yc != 0)).astype(jnp.int32)  # pads excluded
        hist_last = (jnp.zeros((B, m), jnp.int32)
                     .at[rows, jnp.clip(last, 0, m - 1)].add(livei))
        hist_first = (jnp.zeros((B, m), jnp.int32)
                      .at[rows, jnp.clip(first, 0, m - 1)].add(livei))
        below = jnp.cumsum(hist_last, axis=1)                # (B, m)
        above = (jnp.sum(livei, axis=1)[:, None]
                 - jnp.cumsum(hist_first, axis=1))
        score = jnp.where(state.dir_ok, jnp.minimum(below, above), -1)
        v_idx = jnp.argmax(score, axis=1)                    # (B,) first max
    v = V[v_idx]                                         # (B, d)

    # -- 3. coordinator band + support points S -----------------------------
    XWc = jnp.concatenate([Xc, Wxc], axis=1)             # (B, n+cap, d)
    yWc = jnp.concatenate([yc, Wyc], axis=1)
    pjc = _proj_dir(XWc, v)
    posm = yWc == 1
    negm = yWc == -1
    has_p = jnp.any(posm, axis=1)
    has_q = jnp.any(negm, axis=1)
    pj_pos = jnp.where(posm, pjc, -_INF)
    pj_neg = jnp.where(negm, pjc, _INF)
    lo_c = jnp.where(has_p, jnp.max(pj_pos, axis=1), -_INF)
    hi_c = jnp.where(has_q, jnp.min(pj_neg, axis=1), _INF)
    p_pt = _gather_rows(XWc, jnp.argmax(pj_pos, axis=1))
    q_pt = _gather_rows(XWc, jnp.argmin(pj_neg, axis=1))
    nS = has_p.astype(jnp.int32) + has_q.astype(jnp.int32)
    # compacted 2-row block: positive extreme first when present
    S_pts = jnp.stack([jnp.where(has_p[:, None], p_pt, q_pt), q_pt], axis=1)
    S_lab = jnp.stack([jnp.where(has_p, 1, jnp.where(has_q, -1, 0)),
                       jnp.where(has_p & has_q, -1, 0)], axis=1)

    # comm: S broadcast + direction scalars (v, lo_c, hi_c) to k-1 peers
    comm = comm._replace(
        points=comm.points + jnp.where(active, nS * (k - 1), 0),
        scalars=comm.scalars + jnp.where(active, 4 * (k - 1), 0),
        messages=comm.messages + jnp.where(active, 2 * (k - 1), 0),
        rounds=comm.rounds + active.astype(jnp.int32),
    )

    # S lands in every transcript (the coordinator's own sent-ledger included)
    wx, wy, w_fill = state.wx, state.wy, state.w_fill
    lo_w, hi_w = state.lo_w, state.hi_w

    def append_node(j, pts, labs, do):
        nonlocal wx, wy, w_fill, lo_w, hi_w
        wxj, wyj, fj, loj, hij = _append2(
            wx[:, j], wy[:, j], w_fill[:, j], lo_w[:, j], hi_w[:, j],
            pts, labs, do, V)
        wx = wx.at[:, j].set(wxj)
        wy = wy.at[:, j].set(wyj)
        w_fill = w_fill.at[:, j].set(fj)
        lo_w = lo_w.at[:, j].set(loj)
        hi_w = hi_w.at[:, j].set(hij)

    for j in range(k):
        append_node(j, S_pts, S_lab, active)

    # -- 4. ε-early-exit on the coordinator band midpoint -------------------
    band_c = jnp.isfinite(lo_c) & jnp.isfinite(hi_c) & (lo_c < hi_c)
    t_c = 0.5 * (lo_c + hi_c)
    pja = _proj_dir(data.X, v)                           # (B, k, n)
    pred = jnp.where(pja < t_c[:, None, None], 1, -1)    # +1 iff v·x < t
    errs = jnp.sum((pred != data.y) & (data.y != 0), axis=(1, 2))
    term_eps = active & band_c & (errs <= data.budget)
    fire_err = active & band_c                           # error-report msgs
    comm = comm._replace(
        scalars=comm.scalars + jnp.where(fire_err, k - 1, 0),
        messages=comm.messages + jnp.where(fire_err, k - 1, 0),
    )

    # -- 5. per-node extremes along v (post-S transcripts, fill-capped) -----
    if trans_width is None:
        wx_r, wy_r = wx, wy
    else:
        wx_r = wx[:, :, :trans_width]
        wy_r = wy[:, :, :trans_width]
    XW = jnp.concatenate([data.X, wx_r], axis=2)         # (B, k, n+W, d)
    yW = jnp.concatenate([data.y, wy_r], axis=2)
    if extremes_kernel:
        from repro.engine import dataplane
        i_p, i_q = dataplane.median_extremes(v, XW, yW, use_pallas=True)
        has_pk = jnp.any(yW == 1, axis=2)
        has_qk = jnp.any(yW == -1, axis=2)
        p_k = _gather_rows2(XW, i_p)
        q_k = _gather_rows2(XW, i_q)
        lo_k = jnp.where(has_pk, _proj_dir(p_k, v), -_INF)
        hi_k = jnp.where(has_qk, _proj_dir(q_k, v), _INF)
    else:
        has_pk, lo_k, p_k, has_qk, hi_k, q_k = _extremes(XW, yW, v)
    lo_g = jnp.max(lo_k, axis=1)
    hi_g = jnp.min(hi_k, axis=1)
    best_p = _gather_rows(p_k, jnp.argmax(lo_k, axis=1))  # first max node
    best_q = _gather_rows(q_k, jnp.argmin(hi_k, axis=1))

    node_ids = jnp.arange(k)[None, :]
    n_pts_k = has_pk.astype(jnp.int32) + has_qk.astype(jnp.int32)
    reply = ((active & ~term_eps)[:, None] & (node_ids != ci[:, None])
             & (n_pts_k > 0))
    comm = comm._replace(
        points=comm.points + jnp.sum(jnp.where(reply, n_pts_k, 0), axis=1),
        messages=comm.messages + jnp.sum(reply, axis=1, dtype=jnp.int32),
    )
    # node i's reply lands in its own sent-ledger and the coordinator's recv
    for i in range(k):
        E_pts = jnp.stack([jnp.where(has_pk[:, i, None], p_k[:, i], q_k[:, i]),
                           q_k[:, i]], axis=1)
        E_lab = jnp.stack(
            [jnp.where(has_pk[:, i], 1, jnp.where(has_qk[:, i], -1, 0)),
             jnp.where(has_pk[:, i] & has_qk[:, i], -1, 0)], axis=1)
        src_active = active & ~term_eps & (i != ci)
        for j in range(k):
            append_node(j, E_pts, E_lab, src_active & ((j == ci) | (j == i)))

    # -- 6. non-empty global band: terminate; empty: certified pivot --------
    band_g = lo_g < hi_g
    lo_g2 = jnp.where(jnp.isfinite(lo_g), lo_g, hi_g - 2.0)
    hi_g2 = jnp.where(jnp.isfinite(hi_g), hi_g, lo_g2 + 2.0)
    t_star = 0.5 * (lo_g2 + hi_g2)
    fire_band = active & ~term_eps & band_g
    comm = comm._replace(
        bits=comm.bits + jnp.where(fire_band, k - 1, 0),
        messages=comm.messages + jnp.where(fire_band, k - 1, 0),
    )

    fire_pivot = active & ~term_eps & ~band_g
    diff = best_q - best_p
    constraint = sum(V[None, :, i] * diff[:, i, None]
                     for i in range(V.shape[1]))         # (B, m)
    new_ok = state.dir_ok & (constraint > 1e-12)
    # the empty band certifies v itself is inconsistent; prune it explicitly
    # so f32 rounding of v·(q*-p*) ≈ 0 can never keep re-proposing v
    new_ok = new_ok & (jnp.arange(m)[None, :] != v_idx[:, None])
    apply_prune = (fire_pivot & jnp.any(new_ok, axis=1))[:, None]
    dir_ok = jnp.where(apply_prune, new_ok, state.dir_ok)
    comm = comm._replace(
        points=comm.points + jnp.where(fire_pivot, 2 * (k - 1), 0),
        messages=comm.messages + jnp.where(fire_pivot, k - 1, 0),
    )
    P_pts = jnp.stack([best_p, best_q], axis=1)
    P_lab = jnp.where(fire_pivot[:, None],
                      jnp.asarray([1, -1], jnp.int32)[None, :], 0)
    for j in range(k):
        append_node(j, P_pts, P_lab, fire_pivot)

    # -- hypothesis bookkeeping (precedence: band > ε-exit cand > fallback) -
    set_cand = active & band_c
    t_fb = jnp.where(jnp.isfinite(lo_c) & jnp.isfinite(hi_c), t_c, 0.0)
    set_fb = fire_pivot & ~state.h_valid & ~set_cand
    any_set = set_cand | fire_band | set_fb
    h_v = jnp.where(any_set[:, None], v, state.h_v)
    h_t = jnp.where(fire_band, t_star,
                    jnp.where(set_cand, t_c,
                              jnp.where(set_fb, t_fb, state.h_t)))
    h_valid = state.h_valid | any_set

    newly = term_eps | fire_band
    return ProtocolState(
        dir_ok=dir_ok,
        wx=wx, wy=wy, w_fill=w_fill, lo_w=lo_w, hi_w=hi_w,
        turn=state.turn + 1,
        done=state.done | newly,
        converged=state.converged | newly,
        epochs=jnp.where(newly, state.turn // k + 1, state.epochs),
        h_v=h_v, h_t=h_t, h_valid=h_valid,
        comm=comm,
    )


@functools.partial(jax.jit, static_argnames=("k", "max_turns", "cut_kernel",
                                             "extremes_kernel"))
def run_compiled(
    data: EngineData,
    V: jnp.ndarray,
    state0: ProtocolState,
    *,
    k: int,
    max_turns: int,
    cut_kernel: bool = False,
    extremes_kernel: bool = False,
) -> ProtocolState:
    """The whole sweep as one device computation: the constant-folded first
    turn, then while_loop over ``step`` until every instance terminates or
    the turn budget is exhausted.  Always reads transcripts at the full
    static capacity — the cold padded execution model, kept bit-exact as the
    hot path's differential reference (``run_instances(compact=False)``)."""

    def cond(s: ProtocolState):
        return (jnp.min(s.turn) < max_turns) & ~jnp.all(s.done)

    def body(s: ProtocolState):
        return step(data, V, s, k=k, cut_kernel=cut_kernel,
                    extremes_kernel=extremes_kernel)

    return lax.while_loop(cond, body,
                          step(data, V, state0, k=k, first_turn=True,
                               extremes_kernel=extremes_kernel))


_STEP_STATICS = ("k", "first_turn", "cut_kernel", "extremes_kernel",
                 "trans_width")

_step_jit = jax.jit(step, static_argnames=_STEP_STATICS)
# the donated variant: the per-turn output state reuses the input state's
# buffers in place (jax invalidates the donated handle — run_hot keeps a
# strict single-consumer chain, see hotloop.run_hot's donation contract)
_step_jit_don = jax.jit(step, static_argnames=_STEP_STATICS,
                        donate_argnames=("state",))


def _pad_fix(sub: ProtocolState, pad_row: jnp.ndarray) -> ProtocolState:
    """Mark gathered out-of-range rows inert.  done=True masks them out of
    every decision, comm update and append; their zero-filled leaves are
    harmless under the label-0 convention (no valid rows ⇒ every masked
    reduction hits its identity) and the scatter drops them anyway."""
    return sub._replace(done=sub.done | pad_row)


def _hot_turn_impl(
    data: EngineData,
    V: jnp.ndarray,
    state: ProtocolState,
    idx: jnp.ndarray,       # (n_pad,) i32 — active rows, tail = B (dropped)
    n_act: jnp.ndarray,     # () i32 — live prefix of idx
    *,
    k: int,
    first_turn: bool,
    cut_kernel: bool,
    extremes_kernel: bool,
    trans_width: int,
) -> ProtocolState:
    """One compacted MEDIAN turn as a single dispatch (gather → step →
    scatter fused, ``hotloop.gathered_turn``); V is shared across the batch
    and passes through ungathered."""
    step_fn = functools.partial(
        step, k=k, first_turn=first_turn, cut_kernel=cut_kernel,
        extremes_kernel=extremes_kernel, trans_width=trans_width)
    return hotloop.gathered_turn(
        lambda sub_data, sub: step_fn(sub_data, V, sub),
        _pad_fix, data, state, idx, n_act)


_hot_turn = jax.jit(_hot_turn_impl, static_argnames=_STEP_STATICS)
# donated: the scatter-back lands in the input buffers instead of copying
# the full (B, k, cap, …) transcript state every tail turn
_hot_turn_don = jax.jit(_hot_turn_impl, static_argnames=_STEP_STATICS,
                        donate_argnames=("state",))


@functools.lru_cache(maxsize=None)
def _sharded_dispatches(mesh, dspec, sspec, opts, donate):
    """Build (and cache per mesh/spec/static-variant) the sharded per-turn
    dispatches: jitted ``shard_map``s of the full-batch step and of the
    gathered sub-batch turn over the ("data",) mesh.  Everything inside a
    shard is the unmodified single-device program on the local B/S slice —
    MEDIAN decisions are per-instance, so no cross-shard collective exists
    and the sharded sweep is bit-exact against the single-device hot path.
    ``check_rep=False``: every leaf (including the per-instance turn
    counter) shards over the batch axis; nothing is replicated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    k, cut_kernel, extremes_kernel = opts
    vspec = P(None, None)

    def full(data, V, state, *, first_turn, trans_width):
        def body(d, v, s):
            return step(d, v, s, k=k, first_turn=first_turn,
                        cut_kernel=cut_kernel,
                        extremes_kernel=extremes_kernel,
                        trans_width=trans_width)
        return shard_map(body, mesh=mesh, in_specs=(dspec, vspec, sspec),
                         out_specs=sspec, check_rep=False)(data, V, state)

    def sub(data, V, state, idx, n_act, *, first_turn, trans_width):
        # idx is the (S·L,) per-shard block from hotloop.balanced_index and
        # n_act the (S,) per-shard live counts — each shard sees its (L,)
        # local slice and (1,) count and runs the plain gathered turn
        def body(d, v, s, ix, na):
            step_fn = functools.partial(
                step, k=k, first_turn=first_turn, cut_kernel=cut_kernel,
                extremes_kernel=extremes_kernel, trans_width=trans_width)
            return hotloop.gathered_turn(
                lambda sub_data, sub_s: step_fn(sub_data, v, sub_s),
                _pad_fix, d, s, ix, na[0])
        return shard_map(body, mesh=mesh,
                         in_specs=(dspec, vspec, sspec, P("data"), P("data")),
                         out_specs=sspec, check_rep=False)(
                             data, V, state, idx, n_act)

    statics = ("first_turn", "trans_width")
    dn = (2,) if donate else ()
    return (jax.jit(full, static_argnames=statics, donate_argnums=dn),
            jax.jit(sub, static_argnames=statics, donate_argnums=dn))


@jax.jit
def _host_view(state: ProtocolState, ci: jnp.ndarray) -> jnp.ndarray:
    """The hot loop's per-turn host knowledge as one (3, B) i32 transfer:
    done flags, a zero warm row (MEDIAN has no warm carry), and the max
    transcript fill across nodes — stage 5 scans *every* node's transcript,
    so the width compaction keys on the per-instance max, not the
    coordinator's fill alone."""
    return jnp.stack([state.done.astype(jnp.int32),
                      jnp.zeros_like(state.done, jnp.int32),
                      jnp.max(state.w_fill, axis=1)])


def run_hot(
    data: EngineData,
    V: jnp.ndarray,
    state: ProtocolState,
    *,
    k: int,
    max_turns: int,
    cut_kernel: bool = False,
    extremes_kernel: bool = False,
    compact: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    donate: Optional[bool] = None,
    overlap: Optional[bool] = None,
    stats: Optional[dict] = None,
) -> ProtocolState:
    """The MEDIAN sweep as a host-driven turn loop over the jitted ``step``
    (the shared machinery in :mod:`repro.engine.hotloop`, mirroring
    ``maxmarg.run_hot``).

    MEDIAN transcripts are mostly empty early — every turn appends a handful
    of rows into buffers sized for the whole epoch budget — so the per-turn
    band and extremes scans run at ``round_up(max live fill + WIDTH_SLACK,
    8)`` rows instead of the static capacity, and finished instances drop
    out of the dispatch entirely.  Unlike MAXMARG's warm/compacted solver
    path, both compactions are **bit-exact** here: the capped reads drop
    only label-0 rows (mask identities of the max/min reductions) and every
    remaining op is per-row, so hot and cold agree float-for-float, not
    just decision-for-decision (tests/test_median_hot.py pins both).

    ``mesh`` (a 1-D ("data",) mesh, see ``launch.mesh.make_data_mesh``)
    routes every per-turn dispatch through ``shard_map`` over the leading B
    axis — B must be a multiple of the axis size (``pack_instances(...,
    mesh=...)`` pads with born-done dummies) and the sub-batch index comes
    shard-balanced from ``hotloop.balanced_index``.  On the mesh path
    ``donate`` and ``overlap`` default on: donation makes the per-turn
    scatter-back reuse the transcript buffers in place instead of copying
    the full (B, k, cap, d) state, and the double-buffered loop dispatches
    turn t+1 before blocking on turn t's view decode (``WIDTH_GROWTH =
    2k+2`` rows cover the worst one-turn fill growth: the S block, the ≤2
    reply rows from each of k-1 peers, and the pivot pair).  Both remain
    bit-exact — MEDIAN is per-instance (no cross-shard collective) and any
    covering width is exact.  Single-device defaults keep this path the
    unchanged PR-5 oracle; ``donate=True``/``overlap=True`` opt in.
    """
    B = int(state.done.shape[0])
    cap = int(state.wx.shape[2])
    opts = dict(k=k, cut_kernel=cut_kernel, extremes_kernel=extremes_kernel)
    width_growth = 2 * k + 2

    if mesh is not None:
        if not compact:
            raise ValueError("sharded sweeps require the compacted hot path")
        S = int(mesh.shape["data"])
        if B % S:
            raise ValueError(
                f"B={B} not divisible by mesh axis {S}; pack with mesh=")
        donate = True if donate is None else donate
        overlap = True if overlap is None else overlap
        data = device_put_sharded(data, mesh)
        state = device_put_sharded(state, mesh)
        V = jnp.asarray(V, jnp.float32)
        full_j, sub_j = _sharded_dispatches(
            mesh, shard_specs(data), shard_specs(state),
            (k, cut_kernel, extremes_kernel), donate)

        def dispatch_full(s, *, t, width, use_warm):
            return full_j(data, V, s, first_turn=(t == 0), trans_width=width)

        def dispatch_sub(s, idx, n_act, *, t, width, use_warm):
            return sub_j(data, V, s, idx, n_act, first_turn=(t == 0),
                         trans_width=width)

        return hotloop.run_hot(state, k=k, max_turns=max_turns, cap=cap,
                               host_view=_host_view,
                               dispatch_full=dispatch_full,
                               dispatch_sub=dispatch_sub,
                               warm=False, compact=True,
                               width_slack=WIDTH_SLACK,
                               width_growth=width_growth,
                               overlap=overlap, shards=S, stats=stats)

    donate = bool(donate)
    overlap = bool(overlap)
    if donate:
        # donating host numpy buffers is silently ignored — upload first so
        # the in-place scatter actually engages
        state = jax.tree_util.tree_map(jnp.asarray, state)
    step_d = _step_jit_don if donate else _step_jit
    turn_d = _hot_turn_don if donate else _hot_turn

    def dispatch_full(s, *, t, width, use_warm):
        return step_d(data, V, s, first_turn=(t == 0), trans_width=width,
                      **opts)

    def dispatch_sub(s, idx, n_act, *, t, width, use_warm):
        return turn_d(data, V, s, idx, n_act, first_turn=(t == 0),
                      trans_width=width, **opts)

    return hotloop.run_hot(state, k=k, max_turns=max_turns, cap=cap,
                           host_view=_host_view,
                           dispatch_full=dispatch_full,
                           dispatch_sub=dispatch_sub,
                           warm=False, compact=compact,
                           width_slack=WIDTH_SLACK,
                           width_growth=width_growth, overlap=overlap,
                           stats=stats)


def run_instances(
    instances: Sequence[ProtocolInstance],
    *,
    eps: Optional[float] = None,
    n_angles: int = 1024,
    max_epochs: int = 48,
    cut_kernel: Optional[bool] = None,
    extremes_kernel: Optional[bool] = None,
    compact: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    donate: Optional[bool] = None,
    overlap: Optional[bool] = None,
    stats: Optional[dict] = None,
):
    """Run a batch of MEDIAN/k-party instances as one compiled sweep.

    Returns a list of :class:`~repro.core.protocols.one_way.ProtocolResult`,
    one per instance, shaped exactly like the per-instance path's (the
    per-instance path *is* this engine at B=1).

    ``compact=True`` (the default) runs the host-driven hot path
    (``run_hot``: fill-capped transcript reads + finished instances dropped
    from the dispatch); ``compact=False`` keeps the cold padded
    ``run_compiled`` — one while_loop dispatch at worst-case shapes, the
    bit-exact pre-hot-path execution model and the differential reference.
    ``cut_kernel``/``extremes_kernel`` route the per-turn scans through
    their Pallas kernels (default: on TPU only).  ``mesh`` shards the hot
    path over a 1-D ("data",) device mesh (requires ``compact=True``);
    ``donate``/``overlap`` opt the per-turn dispatches into buffer donation
    and the double-buffered host loop (mesh default: both on).  ``stats``
    (a dict) collects host-side observability — on sharded sweeps the
    per-dispatch shard skew (``hotloop.shard_skew``) — and is never read
    for decisions.

    Compile-key contract: ``n_angles``, ``max_epochs``, ``k``, ``d``, the
    kernel toggles, and the mesh topology are static — changing any of
    them compiles a new ``step``.  Shard contents, eps, seeds, and B are
    traced data; the hot path additionally re-keys only on the quantized
    ``(n_pad, width, warm)`` buckets ``hotloop.KEY_LOG`` records, so
    sweeps of any size reuse a handful of compilations.
    """
    from repro.core import classifiers as clf
    from repro.core import geometry as geo
    from repro.core.protocols.one_way import ProtocolResult

    if mesh is not None and not compact:
        raise ValueError("sharded sweeps require the compacted hot path")
    if eps is not None:
        instances = [ProtocolInstance(inst.shards, eps) for inst in instances]
    if cut_kernel is None or extremes_kernel is None:
        from repro.engine import dataplane
        tpu = dataplane.use_pallas_default()
        cut_kernel = tpu if cut_kernel is None else cut_kernel
        extremes_kernel = tpu if extremes_kernel is None else extremes_kernel
    data, state0, k, _cap = pack_instances(
        instances, n_angles=n_angles, max_epochs=max_epochs, mesh=mesh)
    V = jnp.asarray(geo.direction_grid(n_angles), jnp.float32)
    if compact:
        final = run_hot(data, V, state0, k=k, max_turns=k * max_epochs,
                        cut_kernel=cut_kernel,
                        extremes_kernel=extremes_kernel,
                        mesh=mesh, donate=donate, overlap=overlap,
                        stats=stats)
    else:
        final = run_compiled(data, V, state0, k=k, max_turns=k * max_epochs,
                             cut_kernel=cut_kernel,
                             extremes_kernel=extremes_kernel)

    converged = np.asarray(final.converged)
    epochs = np.asarray(final.epochs)
    h_v = np.asarray(final.h_v, np.float64)
    h_t = np.asarray(final.h_t, np.float64)
    # one host transfer per counter array, not one per instance×field
    comm_np = type(final.comm)(*(np.asarray(a) for a in final.comm))
    extra = {"engine": True, "batch": len(instances),
             "selector": "median", "compact": compact}
    if mesh is not None:
        extra["devices"] = int(mesh.shape["data"])
    results: List[ProtocolResult] = []
    for b in range(len(instances)):
        h = clf.LinearSeparator(-h_v[b], float(h_t[b]))
        results.append(ProtocolResult(
            h,
            comm_np.summary(b, dim=2),
            rounds=int(epochs[b]) if converged[b] else max_epochs,
            converged=bool(converged[b]),
            extra=dict(extra),
        ))
    return results
