"""One MAXMARG k-party turn as a pure jitted ``step(state) -> state``.

Faithful vectorization of the per-round-SVM-refit protocol (paper §4.4
two-way MAXMARG and its §7 k-party generalization) that used to live as a
host-side Python loop in ``repro.core.protocols.kparty``.  Each turn the
coordinator ``ci = turn % k`` refits a max-margin separator on everything it
knows — own shard ∪ received transcript — via the batched annealed Pegasos
solver (``repro.core.classifiers._svm_solve_batch``), so a whole sweep of B
hard-margin refits is one device computation per turn and the whole sweep is
one ``lax.while_loop`` dispatch.

Turn structure (mirrors the retired host loop, kept as the differential
oracle in ``benchmarks/legacy_maxmarg.py``):

1. coordinator fits max-margin on own ∪ transcript (the B-batched fit);
2. active-margin support points (functional margin within (1+rtol) of the
   minimum, the ``max_support`` smallest by (margin, index)) are broadcast
   to the k-1 others [k-1 point msgs] and land in their transcripts;
3. every node counts the proposal's errors on its own shard; non-coordinators
   report an all-clear bit [k-1 bit msgs];
4. every violated non-coordinator ships its 2 most-violated points to the
   coordinator [≤2-point msgs, only when violated] — the paper's
   support-vector exchange;
5. terminate when the global error count is within the ε budget.

Padding follows the engine conventions (DESIGN.md): label-0 rows are inert
in the fit (no hinge contribution, gradient normalized by the valid count)
and in every masked selection; transcripts are received-points-only, matching
the host loop's ``Node.recv``.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.classifiers import _svm_solve_batch
from repro.engine.state import (
    BatchCommLog,
    EngineData,
    MaxMargState,
    ProtocolInstance,
    pack_instances_maxmarg,
)

RTOL = 0.15          # active-margin band width, = classifiers.support_points
VIOL_SHIP = 2        # most-violated points shipped per violated node

_INF = jnp.inf


def _append_block(wx, wy, fill, pts, labs, do):
    """Append an r-row block to each instance's transcript at its fill.

    ``pts`` (B, r, d), ``labs`` (B, r) with label-0 marking invalid rows
    (valid rows compacted to the front), ``do`` (B,) gating the append.
    Same invariant as ``median._append2``: writes land at ≥ fill, so masked
    appends only touch label-0 scratch rows the next valid append overwrites.
    """
    labs = jnp.where(do[:, None], labs, 0).astype(jnp.int32)
    nvalid = jnp.sum(labs != 0, axis=1).astype(jnp.int32)

    def upd(w, wl, f, p, l):
        return (lax.dynamic_update_slice(w, p, (f, 0)),
                lax.dynamic_update_slice(wl, l, (f,)))

    wx, wy = jax.vmap(upd)(wx, wy, fill, pts.astype(wx.dtype), labs)
    return wx, wy, fill + nvalid


def _rank_smallest(key: jnp.ndarray) -> jnp.ndarray:
    """Stable rank of each entry under ascending (key, index) order; key rows
    are (B, N) with +inf marking excluded entries."""
    order = jnp.argsort(key, axis=1, stable=True)
    return jnp.argsort(order, axis=1, stable=True)


def _compact_rows(X, y, sel, nsel, r, order=None):
    """Gather the selected rows (≤ r per instance) into a compacted
    (B, r, d) block with label-0 tail slots.  Rows are emitted in ascending
    ``order`` (unique per-row integer keys < N); default is index order —
    the order the host loop ships support points in (``support_points``
    returns ascending indices).  Violation replies pass the margin rank
    instead, matching the host's ``argsort(m)[:2]`` wire order."""
    N = X.shape[1]
    if order is None:
        order = jnp.broadcast_to(jnp.arange(N)[None, :], sel.shape)
    idx_key = jnp.where(sel, order, N)
    cidx = jnp.argsort(idx_key, axis=1, stable=True)[:, :r]       # (B, r)
    pts = jnp.take_along_axis(X, cidx[..., None], axis=1)         # (B, r, d)
    labs = jnp.where(jnp.arange(r)[None, :] < nsel[:, None],
                     jnp.take_along_axis(y, cidx, axis=1), 0)
    return pts, labs.astype(jnp.int32)


def step(
    data: EngineData,
    state: MaxMargState,
    *,
    k: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
) -> MaxMargState:
    """Advance every active instance by one MAXMARG turn (pure, jittable,
    shape-stable — usable under jit/while_loop)."""
    B = state.done.shape[0]
    n_max, d = data.X.shape[2], data.X.shape[3]
    ci = state.turn % k
    active = ~state.done
    comm = state.comm

    # -- 1. batched max-margin refit on coord's own ∪ transcript ------------
    Xc = jnp.take(data.X, ci, axis=1)                  # (B, n_max, d)
    yc = jnp.take(data.y, ci, axis=1)                  # (B, n_max)
    Wxc = jnp.take(state.wx, ci, axis=1)               # (B, cap, d)
    Wyc = jnp.take(state.wy, ci, axis=1)               # (B, cap)
    K = jnp.concatenate([Xc, Wxc], axis=1)             # (B, N, d)
    yK = jnp.concatenate([yc, Wyc], axis=1)            # (B, N) i32
    yKf = yK.astype(K.dtype)
    w, b, _ = _svm_solve_batch(K, yKf, jnp.float32(lam0), steps, stages)

    # -- 2. active-margin support points --------------------------------------
    valid = yK != 0
    m = yKf * (jnp.einsum("bnd,bd->bn", K, w) + b[:, None])
    m_val = jnp.where(valid, m, _INF)
    mmin = jnp.maximum(jnp.min(m_val, axis=1), 1e-12)
    band = valid & (m <= (mmin * (1.0 + RTOL))[:, None])
    sel = band & (_rank_smallest(jnp.where(band, m, _INF)) < max_support)
    nsel = jnp.sum(sel, axis=1).astype(jnp.int32)
    S_pts, S_lab = _compact_rows(K, yK, sel, nsel, max_support)

    # comm: support broadcast to the k-1 others
    comm = comm._replace(
        points=comm.points + jnp.where(active, nsel * (k - 1), 0),
        messages=comm.messages + jnp.where(active, k - 1, 0),
        rounds=comm.rounds + active.astype(jnp.int32),
    )

    wx, wy, w_fill = state.wx, state.wy, state.w_fill
    for j in range(k):
        wxj, wyj, fj = _append_block(
            wx[:, j], wy[:, j], w_fill[:, j], S_pts, S_lab,
            active & (j != ci))
        wx = wx.at[:, j].set(wxj)
        wy = wy.at[:, j].set(wyj)
        w_fill = w_fill.at[:, j].set(fj)

    # -- 3. per-node error counts + all-clear bits --------------------------
    dec = jnp.einsum("bknd,bd->bkn", data.X, w) + b[:, None, None]
    pred = jnp.where(dec > 0, 1, -1)
    err_k = jnp.sum((pred != data.y) & (data.y != 0), axis=2)     # (B, k)
    errs = jnp.sum(err_k, axis=1)
    comm = comm._replace(
        bits=comm.bits + jnp.where(active, k - 1, 0),
        messages=comm.messages + jnp.where(active, k - 1, 0),
    )

    # -- 4. violated nodes ship their 2 most-violated points ----------------
    m_all = data.y.astype(K.dtype) * dec
    key_all = jnp.where(data.y != 0, m_all, _INF)                 # (B, k, n)
    n_valid_k = jnp.sum(data.y != 0, axis=2)
    node_ids = jnp.arange(k)[None, :]
    fire = active[:, None] & (node_ids != ci) & (err_k > 0)
    nv = jnp.minimum(VIOL_SHIP, n_valid_k).astype(jnp.int32)      # (B, k)
    comm = comm._replace(
        points=comm.points + jnp.sum(jnp.where(fire, nv, 0), axis=1),
        messages=comm.messages + jnp.sum(fire, axis=1, dtype=jnp.int32),
    )
    # every reply targets only the coordinator's transcript, so gather that
    # one buffer at the traced index ci and scatter it back — k appends per
    # turn, not the k² a per-target loop would trace
    for i in range(k):
        rank_i = _rank_smallest(key_all[:, i])
        sel_i = (data.y[:, i] != 0) & (rank_i < VIOL_SHIP)
        V_pts, V_lab = _compact_rows(data.X[:, i], data.y[:, i], sel_i,
                                     nv[:, i], VIOL_SHIP, order=rank_i)
        wxc, wyc2, fc = _append_block(
            jnp.take(wx, ci, axis=1), jnp.take(wy, ci, axis=1),
            jnp.take(w_fill, ci, axis=1), V_pts, V_lab, fire[:, i])
        wx = wx.at[:, ci].set(wxc)
        wy = wy.at[:, ci].set(wyc2)
        w_fill = w_fill.at[:, ci].set(fc)

    # -- 5. ε-termination + hypothesis bookkeeping --------------------------
    term = active & (errs <= data.budget)
    return MaxMargState(
        wx=wx, wy=wy, w_fill=w_fill,
        turn=state.turn + 1,
        done=state.done | term,
        converged=state.converged | term,
        epochs=jnp.where(term, state.turn // k + 1, state.epochs),
        h_w=jnp.where(active[:, None], w, state.h_w),
        h_b=jnp.where(active, b, state.h_b),
        comm=comm,
    )


@functools.partial(jax.jit, static_argnames=(
    "k", "max_turns", "max_support", "steps", "stages"))
def run_compiled(
    data: EngineData,
    state0: MaxMargState,
    *,
    k: int,
    max_turns: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
) -> MaxMargState:
    """The whole MAXMARG sweep as one device computation: while_loop over
    ``step`` until every instance terminates or the turn budget runs out."""

    def cond(s: MaxMargState):
        return (s.turn < max_turns) & ~jnp.all(s.done)

    def body(s: MaxMargState):
        return step(data, s, k=k, max_support=max_support, steps=steps,
                    stages=stages, lam0=lam0)

    return lax.while_loop(cond, body, state0)


def run_instances(
    instances: Sequence[ProtocolInstance],
    *,
    eps: Optional[float] = None,
    max_epochs: int = 48,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam: float = 1e-3,
):
    """Run a batch of MAXMARG instances as one compiled sweep.

    Returns :class:`~repro.core.protocols.one_way.ProtocolResult` per
    instance, shaped exactly like the retired host loop's (which survives as
    the differential oracle in ``benchmarks/legacy_maxmarg.py``).
    """
    from repro.core import classifiers as clf
    from repro.core.protocols.one_way import ProtocolResult

    if eps is not None:
        instances = [ProtocolInstance(inst.shards, eps, "maxmarg")
                     for inst in instances]
    data, state0, k, _cap = pack_instances_maxmarg(
        instances, max_epochs=max_epochs, max_support=max_support)
    final = run_compiled(data, state0, k=k, max_turns=k * max_epochs,
                         max_support=max_support, steps=steps, stages=stages,
                         lam0=lam)

    converged = np.asarray(final.converged)
    epochs = np.asarray(final.epochs)
    h_w = np.asarray(final.h_w, np.float64)
    h_b = np.asarray(final.h_b, np.float64)
    comm_np = type(final.comm)(*(np.asarray(a) for a in final.comm))
    d = data.X.shape[3]
    results: List[ProtocolResult] = []
    for i in range(len(instances)):
        h = clf.LinearSeparator(h_w[i], float(h_b[i]))
        results.append(ProtocolResult(
            h,
            comm_np.summary(i, dim=d),
            rounds=int(epochs[i]) if converged[i] else max_epochs,
            converged=bool(converged[i]),
            extra={"engine": True, "batch": len(instances),
                   "selector": "maxmarg"},
        ))
    return results
