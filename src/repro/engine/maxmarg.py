"""One MAXMARG k-party turn as a pure jitted ``step(state) -> state``.

Faithful vectorization of the per-round-SVM-refit protocol (paper §4.4
two-way MAXMARG and its §7 k-party generalization) that used to live as a
host-side Python loop in ``repro.core.protocols.kparty``.  Each turn the
coordinator ``ci = turn % k`` refits a max-margin separator on everything it
knows — own shard ∪ received transcript — via the batched annealed Pegasos
solver (``repro.core.classifiers._svm_solve_batch``), so a whole sweep of B
hard-margin refits is one device computation per turn and the whole sweep is
one ``lax.while_loop`` dispatch.

Turn structure (mirrors the retired host loop, kept as the differential
oracle in ``benchmarks/legacy_maxmarg.py``):

1. coordinator fits max-margin on own ∪ transcript (the B-batched fit);
2. active-margin support points (functional margin within (1+rtol) of the
   minimum, the ``max_support`` smallest by (margin, index)) are broadcast
   to the k-1 others [k-1 point msgs] and land in their transcripts;
3. every node counts the proposal's errors on its own shard; non-coordinators
   report an all-clear bit [k-1 bit msgs];
4. every violated non-coordinator ships its 2 most-violated points to the
   coordinator [≤2-point msgs, only when violated] — the paper's
   support-vector exchange;
5. terminate when the global error count is within the ε budget.

Padding follows the engine conventions (DESIGN.md): label-0 rows are inert
in the fit (no hinge contribution, gradient normalized by the valid count)
and in every masked selection; transcripts are received-points-only, matching
the host loop's ``Node.recv``.

Hot path (DESIGN.md §warm-start & transcript compaction, §shared hot loop):
``run_hot`` drives the same ``step`` from the host one turn at a time — on
the selector-generic machinery in :mod:`repro.engine.hotloop` — so it can
(a) warm-start every refit from a carried separator, (b) slice the
coordinator's transcript gather down to the bucket's live width
(``w_fill``) instead of the worst-case capacity, and (c) drop finished
instances from the dispatch.  The warm carry is *per-node* by default
(``per_node=True``): each node carries the most recent proposal it verified
clean on everything it knows (zero errors on its shard + margin > 0 on its
transcript) and polishes from that when it next coordinates, threaded as
the ``(k,)``-leading leaves ``MaxMargState.c_w``/``c_b``/``c_valid`` with
the incremental clean-carry flags ``warm_node``.  In long k-party
multi-epoch sweeps a clean proposal adopted mid-epoch usually survives to
the node's own turn, where the single previous-*turn* carry (the
``per_node=False`` mode, kept for the differential latch tests) is only
ever checked against the immediately-next coordinator and rarely latches.
All layers are
decision-preserving — the hard-margin optimum is transcript-determined, so
warm/compacted and the cold padded ``run_compiled`` path agree on
comm/rounds/convergence on every tested grid (tests/test_maxmarg_warm.py
enforces it; ``run_instances(warm=False, compact=False)`` keeps the exact
legacy-oracle execution model).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.classifiers import _svm_solve_batch
from repro.engine import hotloop
from repro.engine.state import (
    EngineData,
    MaxMargState,
    ProtocolInstance,
    device_put_sharded,
    pack_instances_maxmarg,
    shard_specs,
)
from repro.kernels import ops, ref

RTOL = 0.15          # active-margin band width, = classifiers.support_points
VIOL_SHIP = 2        # most-violated points shipped per violated node

# (B, N, ...) × (B,) -> (B, ...): coordinator-indexed gathers — ci is a
# per-instance vector (see hotloop.gather_rows)
_gather_rows = hotloop.gather_rows


def _append_block(wx, wy, fill, pts, labs, do):
    """Append an r-row block to each instance's transcript at its fill.

    ``pts`` (B, r, d), ``labs`` (B, r) with label-0 marking invalid rows
    (valid rows compacted to the front), ``do`` (B,) gating the append.
    Same invariant as ``median._append2``: writes land at ≥ fill, so masked
    appends only touch label-0 scratch rows the next valid append overwrites.
    """
    labs = jnp.where(do[:, None], labs, 0).astype(jnp.int32)
    nvalid = jnp.sum(labs != 0, axis=1).astype(jnp.int32)

    def upd(w, wl, f, p, l):
        return (lax.dynamic_update_slice(w, p, (f, 0)),
                lax.dynamic_update_slice(wl, l, (f,)))

    wx, wy = jax.vmap(upd)(wx, wy, fill, pts.astype(wx.dtype), labs)
    return wx, wy, fill + nvalid


def _compact_rows(X, y, sel, nsel, r, order=None):
    """Gather the selected rows (≤ r per instance) into a compacted
    (B, r, d) block with label-0 tail slots.  Rows are emitted in ascending
    ``order`` (unique per-row integer keys < N); default is index order —
    the order the host loop ships support points in (``support_points``
    returns ascending indices).  Violation replies pass the margin rank
    instead, matching the host's ``argsort(m)[:2]`` wire order."""
    N = X.shape[1]
    if order is None:
        order = jnp.broadcast_to(jnp.arange(N)[None, :], sel.shape)
    idx_key = jnp.where(sel, order, N)
    cidx = jnp.argsort(idx_key, axis=1, stable=True)[:, :r]       # (B, r)
    pts = jnp.take_along_axis(X, cidx[..., None], axis=1)         # (B, r, d)
    labs = jnp.where(jnp.arange(r)[None, :] < nsel[:, None],
                     jnp.take_along_axis(y, cidx, axis=1), 0)
    return pts, labs.astype(jnp.int32)


def step(
    data: EngineData,
    state: MaxMargState,
    *,
    k: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
    trans_width: Optional[int] = None,
    warm: bool = False,
    per_node: bool = True,
    fused_kernel: bool = False,
    solver_kernel: Optional[bool] = None,
) -> MaxMargState:
    """Advance every active instance by one MAXMARG turn (pure, jittable,
    shape-stable — usable under jit/while_loop).

    ``trans_width`` (static) compacts the coordinator-transcript gather to
    the first ``trans_width`` rows — sound whenever it covers every active
    instance's live fill (``run_hot`` guarantees this; ``None`` gathers the
    full capacity).  ``warm`` (static) threads a carried separator into the
    refit's polish pre-stage: the last proposal the coordinator *verified
    clean* on everything it knows when ``per_node`` (static, the default —
    see the module docstring), else the previous turn's proposal.
    ``fused_kernel`` (static) routes the
    post-refit margin scan through the fused Pallas support/violation kernel
    (``kernels.support_margin.maxmarg_turn_scan_batched``, the TPU artifact)
    instead of its jnp reference — both produce identical integer decisions
    (bit-for-bit tested).  ``solver_kernel`` (static) selects the *refit*
    path the same way: the tiled Pegasos stage kernel
    (``kernels.pegasos``, jnp twin off-TPU) vs the classic d-unrolled
    loop; ``None`` defers to ``_svm_solve_batch``'s TPU-default."""
    B = state.done.shape[0]
    n_max, d = data.X.shape[2], data.X.shape[3]
    ci = state.turn % k                                # (B,) per-instance
    active = ~state.done
    comm = state.comm

    # -- 1. batched max-margin refit on coord's own ∪ transcript ------------
    Xc = _gather_rows(data.X, ci)                      # (B, n_max, d)
    yc = _gather_rows(data.y, ci)                      # (B, n_max)
    Wxc = _gather_rows(state.wx, ci)                   # (B, cap, d)
    Wyc = _gather_rows(state.wy, ci)                   # (B, cap)
    if trans_width is not None:                        # compacted gather
        Wxc = Wxc[:, :trans_width]
        Wyc = Wyc[:, :trans_width]
    if Wxc.shape[1]:
        K = jnp.concatenate([Xc, Wxc], axis=1)         # (B, N, d)
        yK = jnp.concatenate([yc, Wyc], axis=1)        # (B, N) i32
    else:                                              # empty transcripts
        K, yK = Xc, yc
    yKf = yK.astype(K.dtype)
    if warm:
        if per_node and k > 2:
            # the per-node carry the coordinator verified clean; at k=2 the
            # carry bookkeeping is statically skipped (see below), so warm
            # falls back to the single previous-turn carry there
            w0 = _gather_rows(state.c_w, ci)
            b0 = _gather_rows(state.c_b, ci)
            wok = _gather_rows(state.c_valid, ci) \
                & _gather_rows(state.warm_node, ci)
        else:
            w0, b0, wok = state.h_w, state.h_b, state.h_valid
        # clean0 is the solver's own polish gate (carried separator
        # classifies the fit set cleanly) — the latch counter's source,
        # observability only, never a protocol decision
        w, b, fit_ok, clean0 = _svm_solve_batch(
            K, yKf, jnp.float32(lam0), steps, stages,
            w0=w0, b0=b0, warm_ok=wok, return_gate=True,
            kernel=solver_kernel)
    else:
        w, b, fit_ok = _svm_solve_batch(K, yKf, jnp.float32(lam0), steps,
                                        stages, kernel=solver_kernel)
        clean0 = jnp.zeros_like(state.done)

    # -- 2-4 scans: one fused pass over the proposal --------------------------
    # support band ranks on the fit set, per-node error counts, and per-node
    # most-violated ranks — the Pallas kernel and its vmap reference return
    # identical int32 decisions (tests/test_kernels.py)
    if fused_kernel:
        sup_rank, err_k, viol_rank = ops.support_violation_batch(
            w, b, K, yK, data.X, data.y, rtol=RTOL,
            max_support=max_support, viol_ship=VIOL_SHIP)
    else:
        sup_rank, err_k, viol_rank = ref.maxmarg_turn_batch_ref(
            w, b, K, yK, data.X, data.y, rtol=RTOL,
            max_support=max_support, viol_ship=VIOL_SHIP)

    # -- 2. active-margin support points --------------------------------------
    sel = sup_rank < max_support
    nsel = jnp.sum(sel, axis=1).astype(jnp.int32)
    S_pts, S_lab = _compact_rows(K, yK, sel, nsel, max_support)

    # comm: support broadcast to the k-1 others
    comm = comm._replace(
        points=comm.points + jnp.where(active, nsel * (k - 1), 0),
        messages=comm.messages + jnp.where(active, k - 1, 0),
        rounds=comm.rounds + active.astype(jnp.int32),
    )

    wx, wy, w_fill = state.wx, state.wy, state.w_fill
    for j in range(k):
        wxj, wyj, fj = _append_block(
            wx[:, j], wy[:, j], w_fill[:, j], S_pts, S_lab,
            active & (j != ci))
        wx = wx.at[:, j].set(wxj)
        wy = wy.at[:, j].set(wyj)
        w_fill = w_fill.at[:, j].set(fj)

    # -- 3. per-node error counts + all-clear bits --------------------------
    errs = jnp.sum(err_k, axis=1)
    comm = comm._replace(
        bits=comm.bits + jnp.where(active, k - 1, 0),
        messages=comm.messages + jnp.where(active, k - 1, 0),
    )

    # -- 4. violated nodes ship their 2 most-violated points ----------------
    n_valid_k = jnp.sum(data.y != 0, axis=2)
    node_ids = jnp.arange(k)[None, :]
    fire = active[:, None] & (node_ids != ci[:, None]) & (err_k > 0)
    nv = jnp.minimum(VIOL_SHIP, n_valid_k).astype(jnp.int32)      # (B, k)
    comm = comm._replace(
        points=comm.points + jnp.sum(jnp.where(fire, nv, 0), axis=1),
        messages=comm.messages + jnp.sum(fire, axis=1, dtype=jnp.int32),
    )
    # every reply targets only the coordinator's transcript, so gather that
    # one buffer at the per-instance index ci and scatter it back — k appends
    # per turn, not the k² a per-target loop would trace
    bidx = jnp.arange(B)
    for i in range(k):
        rank_i = viol_rank[:, i]
        sel_i = rank_i < VIOL_SHIP
        V_pts, V_lab = _compact_rows(data.X[:, i], data.y[:, i], sel_i,
                                     nv[:, i], VIOL_SHIP, order=rank_i)
        wxc, wyc2, fc = _append_block(
            _gather_rows(wx, ci), _gather_rows(wy, ci),
            _gather_rows(w_fill, ci), V_pts, V_lab, fire[:, i])
        wx = wx.at[bidx, ci].set(wxc)
        wy = wy.at[bidx, ci].set(wyc2)
        w_fill = w_fill.at[bidx, ci].set(fc)

    # -- 5. ε-termination + hypothesis/warm-carry bookkeeping ---------------
    term = active & (errs <= data.budget)
    # single-carry latch precondition: can the next turn's coordinator warm-
    # start from *this* proposal?  Only if it already classifies that shard
    # cleanly (necessary for the polish latch's clean-carry gate)
    err_next = _gather_rows(err_k, (ci + 1) % k)

    # per-node carries: each node *adopts* this turn's proposal as its carry
    # whenever it verifies the proposal clean on everything it knows — zero
    # errors on its own shard (the err_k bits it reports anyway) and margin
    # > 0 on every row of its current transcript.  A node's own fit can
    # never survive to its next turn (a continuing turn always lands
    # violation replies the fit misclassifies in its transcript), but a
    # *later, cleaner* proposal adopted mid-epoch usually can — that is what
    # latches in long k-party sweeps.  Flags then degrade incrementally:
    # the broadcast S block is clean under an adopted carry by construction
    # (its own support set), checked row-wise under a kept carry, and any
    # violation reply dirties the coordinator's transcript conservatively.
    # The carries are only ever read by per-node warm refits, so the
    # bookkeeping is traced only when this step may feed one (``per_node``
    # static — the runners pass per_node=False for cold and single-carry
    # runs).  At k=2 the mechanism is additionally provably inert — the
    # lone non-coordinator verifying the proposal clean IS the
    # ε-termination (errs = its error count ≤ budget), so adoption implies
    # the instance is done — and skipped regardless (k is static).
    if per_node and k > 2:
        is_ci = (jnp.arange(k)[None, :] == ci[:, None])  # (B, k)
        viol_any = jnp.any(fire, axis=1)                 # (B,)
        Wx_all = state.wx if trans_width is None \
            else state.wx[:, :, :trans_width]            # pre-append rows
        Wy_all = state.wy if trans_width is None \
            else state.wy[:, :, :trans_width]
        mT = Wy_all.astype(K.dtype) * (
            sum(Wx_all[..., i] * w[:, None, None, i] for i in range(d))
            + b[:, None, None])                          # (B, k, W)
        trans_clean = jnp.all((Wy_all == 0) | (mT > 0.0), axis=2)
        adopt = active[:, None] & fit_ok[:, None] & (err_k == 0) \
            & trans_clean
        c_w = jnp.where(adopt[..., None], w[:, None, :], state.c_w)
        c_b = jnp.where(adopt, b[:, None], state.c_b)
        mS = S_lab[:, None, :].astype(K.dtype) * (
            sum(S_pts[:, None, :, i].astype(K.dtype) * c_w[:, :, None, i]
                for i in range(d)) + c_b[:, :, None])    # (B, k, r)
        s_clean = jnp.all((S_lab[:, None, :] == 0) | (mS > 0.0), axis=2)
        recv = active[:, None] & ~is_ci                  # S recipients
        viol_hit = is_ci & (viol_any & active)[:, None]  # replies landed
        flag_adopt = jnp.where(is_ci, ~viol_any[:, None], True)
        flag_keep = state.warm_node & (s_clean | ~recv) & ~viol_hit
        c_valid = state.c_valid | adopt
        warm_node = jnp.where(adopt, flag_adopt, flag_keep)
    else:
        c_w, c_b = state.c_w, state.c_b
        c_valid, warm_node = state.c_valid, state.warm_node
    return MaxMargState(
        wx=wx, wy=wy, w_fill=w_fill,
        turn=state.turn + 1,
        done=state.done | term,
        converged=state.converged | term,
        epochs=jnp.where(term, state.turn // k + 1, state.epochs),
        h_w=jnp.where(active[:, None], w, state.h_w),
        h_b=jnp.where(active, b, state.h_b),
        h_valid=state.h_valid | active,
        warm_turn=jnp.where(active, err_next == 0, state.warm_turn),
        c_w=c_w, c_b=c_b,
        c_valid=c_valid,
        warm_node=warm_node,
        latches=state.latches + (active & clean0).astype(jnp.int32),
        comm=comm,
    )


@functools.partial(jax.jit, static_argnames=(
    "k", "max_turns", "max_support", "steps", "stages", "warm", "per_node",
    "fused_kernel", "solver_kernel"))
def run_compiled(
    data: EngineData,
    state0: MaxMargState,
    *,
    k: int,
    max_turns: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
    warm: bool = False,
    per_node: bool = True,
    fused_kernel: bool = False,
    solver_kernel: Optional[bool] = None,
) -> MaxMargState:
    """The whole MAXMARG sweep as one device computation: while_loop over
    ``step`` until every instance terminates or the turn budget runs out.
    Always solves at the full padded transcript width — with ``warm=False``
    (the default) this is the exact pre-compaction execution model, kept as
    the legacy-parity reference for the hot path."""

    def cond(s: MaxMargState):
        return (jnp.min(s.turn) < max_turns) & ~jnp.all(s.done)

    def body(s: MaxMargState):
        return step(data, s, k=k, max_support=max_support, steps=steps,
                    stages=stages, lam0=lam0, warm=warm,
                    per_node=per_node and warm,
                    fused_kernel=fused_kernel, solver_kernel=solver_kernel)

    return lax.while_loop(cond, body, state0)


_STEP_STATICS = ("k", "max_support", "steps", "stages", "trans_width",
                 "warm", "per_node", "fused_kernel", "solver_kernel")

_step_jit = jax.jit(step, static_argnames=_STEP_STATICS)
# the donated variant: the per-turn output reuses the input state's buffers
# in place (jax invalidates the donated handle — run_hot keeps a strict
# single-consumer chain, see hotloop.run_hot's donation contract)
_step_jit_don = jax.jit(step, static_argnames=_STEP_STATICS,
                        donate_argnames=("state",))


def _pad_fix(sub: MaxMargState, pad_row: jnp.ndarray) -> MaxMargState:
    """Mark gathered out-of-range rows inert: done=True masks them out of
    every decision and comm update, and trusting their (zero) carries lets
    the warm polish latch them instantly (zero data ⇒ infinite min margin),
    so padding can never force an annealing stage the live rows don't
    need."""
    return sub._replace(done=sub.done | pad_row,
                        h_valid=sub.h_valid | pad_row,
                        c_valid=sub.c_valid | pad_row[:, None],
                        warm_node=sub.warm_node | pad_row[:, None])


def _hot_turn_impl(
    data: EngineData,
    state: MaxMargState,
    idx: jnp.ndarray,       # (n_pad,) i32 — active rows, tail = B (dropped)
    n_act: jnp.ndarray,     # () i32 — live prefix of idx
    *,
    k: int,
    max_support: int,
    steps: int,
    stages: int,
    lam0: float,
    trans_width: int,
    warm: bool,
    per_node: bool,
    fused_kernel: bool,
    solver_kernel: Optional[bool] = None,
) -> MaxMargState:
    """One compacted turn as a single dispatch: gather the active instances,
    advance them by one ``step`` at the compacted transcript width, scatter
    the results back (``hotloop.gathered_turn`` — fusing the gather/scatter
    into the turn's jit keeps the host loop at one device computation per
    turn; eager per-leaf scatters cost more than the refit they wrap on
    CPU)."""
    step_fn = functools.partial(
        step, k=k, max_support=max_support, steps=steps, stages=stages,
        lam0=lam0, trans_width=trans_width, warm=warm, per_node=per_node,
        fused_kernel=fused_kernel, solver_kernel=solver_kernel)
    return hotloop.gathered_turn(step_fn, _pad_fix, data, state, idx, n_act)


_hot_turn = jax.jit(_hot_turn_impl, static_argnames=_STEP_STATICS)
# donated: the scatter-back lands in the input buffers instead of copying
# the full (B, k, cap, …) transcript state every tail turn
_hot_turn_don = jax.jit(_hot_turn_impl, static_argnames=_STEP_STATICS,
                        donate_argnames=("state",))


@functools.lru_cache(maxsize=None)
def _sharded_dispatches(mesh, dspec, sspec, opts, donate):
    """Build (and cache per mesh/spec/static-variant) the sharded per-turn
    dispatches: jitted ``shard_map``s of the full-batch step and of the
    gathered sub-batch turn over the ("data",) mesh.  Everything inside a
    shard is the unmodified single-device program on the local B/S slice —
    MAXMARG decisions are per-instance, so no cross-shard collective exists.
    ``check_rep=False``: every leaf (including the per-instance turn
    counter) shards over the batch axis; nothing is replicated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    k, max_support, steps, stages, lam0, fused_kernel, solver_kernel = opts

    def full(data, state, *, trans_width, warm, per_node):
        def body(d, s):
            return step(d, s, k=k, max_support=max_support, steps=steps,
                        stages=stages, lam0=lam0, trans_width=trans_width,
                        warm=warm, per_node=per_node,
                        fused_kernel=fused_kernel,
                        solver_kernel=solver_kernel)
        return shard_map(body, mesh=mesh, in_specs=(dspec, sspec),
                         out_specs=sspec, check_rep=False)(data, state)

    def sub(data, state, idx, n_act, *, trans_width, warm, per_node):
        # idx is the (S·L,) per-shard block from hotloop.balanced_index and
        # n_act the (S,) per-shard live counts — each shard sees its (L,)
        # local slice and (1,) count and runs the plain gathered turn
        def body(d, s, ix, na):
            step_fn = functools.partial(
                step, k=k, max_support=max_support, steps=steps,
                stages=stages, lam0=lam0, trans_width=trans_width,
                warm=warm, per_node=per_node, fused_kernel=fused_kernel,
                solver_kernel=solver_kernel)
            return hotloop.gathered_turn(step_fn, _pad_fix, d, s, ix, na[0])
        return shard_map(body, mesh=mesh,
                         in_specs=(dspec, sspec, P("data"), P("data")),
                         out_specs=sspec, check_rep=False)(
                             data, state, idx, n_act)

    statics = ("trans_width", "warm", "per_node")
    dn = (1,) if donate else ()
    return (jax.jit(full, static_argnames=statics, donate_argnums=dn),
            jax.jit(sub, static_argnames=statics, donate_argnums=dn))


@functools.partial(jax.jit, static_argnames=("per_node",))
def _host_view(state: MaxMargState, ci: jnp.ndarray, *,
               per_node: bool = True) -> jnp.ndarray:
    """The hot loop's per-turn host knowledge as one (3, B) i32 transfer:
    done flags, the upcoming coordinator's warm-latch flags, and the
    transcript fills the width compaction keys on.  With per-node carry
    tracking the fill row is the max across *all* nodes — the carry
    bookkeeping's ``trans_clean`` scan reads every transcript, so the
    capped width must cover every live row (the `w_fill` contract, DESIGN
    §shared hot loop); otherwise only the coordinator's transcript is read
    and its fill alone keys the cap."""
    k = state.w_fill.shape[1]
    track = per_node and k > 2
    wflag = (jnp.take(state.warm_node, ci, axis=1) if track
             else state.warm_turn)
    fills = (jnp.max(state.w_fill, axis=1) if track
             else jnp.take(state.w_fill, ci, axis=1))
    return jnp.stack([state.done.astype(jnp.int32),
                      wflag.astype(jnp.int32),
                      fills])


def run_hot(
    data: EngineData,
    state: MaxMargState,
    *,
    k: int,
    max_turns: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
    warm: bool = True,
    per_node: bool = True,
    compact: bool = True,
    fused_kernel: bool = False,
    solver_kernel: Optional[bool] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    donate: Optional[bool] = None,
    overlap: Optional[bool] = None,
    stats: Optional[dict] = None,
) -> MaxMargState:
    """The MAXMARG sweep as a host-driven turn loop over the jitted ``step``
    (the shared machinery in :mod:`repro.engine.hotloop`).

    Relative to ``run_compiled`` (one while_loop at worst-case shapes) this
    trades one dispatch per *turn* — protocol sweeps converge in a few
    epochs — for the two compactions a while_loop cannot express, plus
    warm-started refits:

    * **width compaction**: the refit gathers the coordinator transcript at
      ``round_up(max live fill, 8)`` rows instead of the full static
      capacity, re-padding only when the bucket's max live length grows
      (widths are monotone, so each sweep compiles a handful of step
      variants that later sweeps of the same shape reuse);
    * **batch compaction**: finished instances drop out of the dispatch
      (the live set rounds up to a multiple of 4 with inert zero-filled
      padding rows), so a long tail of unconverged instances stops paying
      for the whole sweep's refit math;
    * **warm refits** (``warm=True``): turn ≥ 1 refits polish a carried
      separator instead of annealing from zero — the last proposal each
      node verified clean on its own data when ``per_node`` (the default;
      see the module docstring), else the previous turn's proposal
      (see ``classifiers._svm_solve_batch``).

    Per-instance results are identical in every protocol decision to
    ``run_compiled`` — solver math differs only by float reassociation
    across padding widths and by warm-vs-cold approximation of the same
    transcript-determined optimum (tests/test_maxmarg_warm.py pins comm/
    rounds/convergence and the canonicalized separator across both paths).

    ``mesh`` (a 1-D ("data",) mesh, ``launch.mesh.make_data_mesh``) routes
    every dispatch through ``shard_map`` over the leading B axis — B must
    be a multiple of the axis size (``pack_instances_maxmarg(..., mesh=``
    pads with born-done dummies) and sub-batch turns come shard-balanced
    from ``hotloop.balanced_index``.  ``donate``/``overlap`` default on
    there (in-place scatter-back + double-buffered host loop; the
    stale-view width grows by the worst one-turn transcript growth:
    ``max(max_support, VIOL_SHIP·(k−1))`` — the S broadcast on a receiving
    node vs the ≤2-row replies from each of k−1 peers on the coordinator).
    MAXMARG decisions are per-instance, so sharding itself is exact; the
    stale warm-gate under ``overlap`` may make different — equally valid —
    polish-skip choices, decision-preserving like the warm gate itself.
    Single-device defaults keep this path the unchanged oracle;
    ``donate=True``/``overlap=True`` opt in.
    """
    B = int(state.done.shape[0])
    cap = int(state.wx.shape[2])
    # carry bookkeeping must run on *every* turn of a warm per-node run
    # (including turns whose polish dispatch is skipped) but on none of a
    # cold or single-carry run, so the tracking flag is run-level, not
    # per-dispatch
    track = per_node and warm
    opts = dict(k=k, max_support=max_support, steps=steps, stages=stages,
                lam0=lam0, per_node=track, fused_kernel=fused_kernel,
                solver_kernel=solver_kernel)
    width_growth = max(max_support, VIOL_SHIP * (k - 1))

    def host_view(s, ci):
        return _host_view(s, ci, per_node=track)

    if mesh is not None:
        if not compact:
            raise ValueError("sharded sweeps require the compacted hot path")
        S = int(mesh.shape["data"])
        if B % S:
            raise ValueError(
                f"B={B} not divisible by mesh axis {S}; pack with mesh=")
        donate = True if donate is None else donate
        overlap = True if overlap is None else overlap
        data = device_put_sharded(data, mesh)
        state = device_put_sharded(state, mesh)
        full_j, sub_j = _sharded_dispatches(
            mesh, shard_specs(data), shard_specs(state),
            (k, max_support, steps, stages, lam0, fused_kernel,
             solver_kernel), donate)

        def dispatch_full(s, *, t, width, use_warm):
            return full_j(data, s, trans_width=width, warm=use_warm,
                          per_node=track)

        def dispatch_sub(s, idx, n_act, *, t, width, use_warm):
            return sub_j(data, s, idx, n_act, trans_width=width,
                         warm=use_warm, per_node=track)

        return hotloop.run_hot(state, k=k, max_turns=max_turns, cap=cap,
                               host_view=host_view,
                               dispatch_full=dispatch_full,
                               dispatch_sub=dispatch_sub, warm=warm,
                               compact=True, width_growth=width_growth,
                               overlap=overlap, shards=S, stats=stats)

    donate = bool(donate)
    overlap = bool(overlap)
    if donate:
        # donating host numpy buffers is silently ignored — upload first so
        # the in-place scatter actually engages
        state = jax.tree_util.tree_map(jnp.asarray, state)
    step_d = _step_jit_don if donate else _step_jit
    turn_d = _hot_turn_don if donate else _hot_turn

    def dispatch_full(s, *, t, width, use_warm):
        return step_d(data, s, trans_width=width, warm=use_warm, **opts)

    def dispatch_sub(s, idx, n_act, *, t, width, use_warm):
        return turn_d(data, s, idx, n_act, trans_width=width,
                      warm=use_warm, **opts)

    return hotloop.run_hot(state, k=k, max_turns=max_turns, cap=cap,
                           host_view=host_view, dispatch_full=dispatch_full,
                           dispatch_sub=dispatch_sub, warm=warm,
                           compact=compact, width_growth=width_growth,
                           overlap=overlap, stats=stats)


def run_instances(
    instances: Sequence[ProtocolInstance],
    *,
    eps: Optional[float] = None,
    max_epochs: int = 48,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam: float = 1e-3,
    warm: bool = True,
    per_node: bool = True,
    compact: bool = True,
    fused_kernel: Optional[bool] = None,
    solver_kernel: Optional[bool] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    donate: Optional[bool] = None,
    overlap: Optional[bool] = None,
    stats: Optional[dict] = None,
):
    """Run a batch of MAXMARG instances as one compiled sweep.

    Returns :class:`~repro.core.protocols.one_way.ProtocolResult` per
    instance, shaped exactly like the retired host loop's (which survives as
    the differential oracle in ``benchmarks/legacy_maxmarg.py``).

    ``warm``/``compact`` select the hot path (``run_hot``); passing both as
    False runs the single-dispatch cold padded ``run_compiled`` — the exact
    pre-compaction execution model, kept for legacy-oracle parity and the
    warm-vs-cold differential gate.  ``per_node`` picks the warm-carry mode
    (the last proposal each node verified clean vs the previous turn's
    proposal — see the module docstring and ``run_hot``).
    ``fused_kernel`` routes the per-turn margin scans through
    the Pallas kernel (default: on TPU only, like the MEDIAN selector's
    ``cut_kernel``); ``solver_kernel`` does the same for the refit solver
    itself — the tiled Pegasos stage kernel with its fused first-0-error
    latch (jnp dot-contraction twin off-TPU; same TPU-only default).  ``mesh`` shards the hot path over a 1-D ("data",)
    device mesh (requires ``compact=True``); ``donate``/``overlap`` opt the
    per-turn dispatches into buffer donation and the double-buffered host
    loop (mesh default: both on).

    Compile-key contract: ``max_epochs``, ``max_support``, ``steps``,
    ``stages``, ``k``, ``d``, ``per_node``, the kernel toggles, and the
    mesh topology are static — changing any of them compiles a new
    ``step``.  Shard contents, eps, ``lam``, seeds, and B are traced
    data; the hot path re-keys only on the quantized
    ``(n_pad, width, warm)`` buckets ``hotloop.KEY_LOG`` records.
    """
    from repro.core import classifiers as clf
    from repro.core.protocols.one_way import ProtocolResult
    from repro.engine import dataplane

    if mesh is not None and not compact:
        raise ValueError("sharded sweeps require the compacted hot path")
    if eps is not None:
        instances = [ProtocolInstance(inst.shards, eps, "maxmarg")
                     for inst in instances]
    if fused_kernel is None:
        fused_kernel = dataplane.use_pallas_default()
    if solver_kernel is None:
        solver_kernel = dataplane.use_pallas_default()
    data, state0, k, _cap = pack_instances_maxmarg(
        instances, max_epochs=max_epochs, max_support=max_support, mesh=mesh)
    if warm or compact:
        final = run_hot(data, state0, k=k, max_turns=k * max_epochs,
                        max_support=max_support, steps=steps, stages=stages,
                        lam0=lam, warm=warm, per_node=per_node,
                        compact=compact, fused_kernel=fused_kernel,
                        solver_kernel=solver_kernel, mesh=mesh,
                        donate=donate, overlap=overlap, stats=stats)
    else:
        final = run_compiled(data, state0, k=k, max_turns=k * max_epochs,
                             max_support=max_support, steps=steps,
                             stages=stages, lam0=lam, per_node=per_node,
                             fused_kernel=fused_kernel,
                             solver_kernel=solver_kernel)

    converged = np.asarray(final.converged)
    epochs = np.asarray(final.epochs)
    h_w = np.asarray(final.h_w, np.float64)
    h_b = np.asarray(final.h_b, np.float64)
    latches = np.asarray(final.latches)
    comm_np = type(final.comm)(*(np.asarray(a) for a in final.comm))
    d = data.X.shape[3]
    extra = {"engine": True, "batch": len(instances),
             "selector": "maxmarg", "warm": warm, "compact": compact,
             "per_node": per_node}
    if mesh is not None:
        extra["devices"] = int(mesh.shape["data"])
    results: List[ProtocolResult] = []
    for i in range(len(instances)):
        h = clf.LinearSeparator(h_w[i], float(h_b[i]))
        results.append(ProtocolResult(
            h,
            comm_np.summary(i, dim=d),
            rounds=int(epochs[i]) if converged[i] else max_epochs,
            converged=bool(converged[i]),
            extra=dict(extra, warm_latches=int(latches[i])),
        ))
    return results
