"""Batched bulk scans over sweep state: Pallas on TPU / jitted JAX on CPU.

The two shapes are exactly the single-instance kernels' with a leading batch
axis:

* ``ranges``: per-instance consistent-threshold intervals over a transcript
  — (B, m, cap) masked matmul-reduce;
* ``uncertain``: per-instance SOU membership — (B, m, n) masked matmul-any.

On TPU both dispatch to the batch-grid Pallas kernels
(``repro.kernels.support_margin.{threshold_ranges_batched,
uncertain_mask_batched}``); elsewhere to the jitted pure-jnp oracles in
``repro.kernels.ref`` (interpret-mode Pallas inside a hot loop would be
pathologically slow).  Outputs are normalized to ±inf sentinels.

Note these are the *bulk-scan* entry points — SOU diagnostics over final
sweep state, and the rescan oracle that validates the engine's incremental
ranges (tests/test_engine.py).  The engine's in-loop data plane is the fused
inline pipeline in ``median.step`` plus append-time range maintenance; it
does not route through this module (see DESIGN.md §data plane).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.support_margin import BIG


def use_pallas_default() -> bool:
    return jax.default_backend() == "tpu"


def ranges(
    V: jnp.ndarray,      # (m, d) shared directions
    Wx: jnp.ndarray,     # (B, cap, d) transcripts
    Wy: jnp.ndarray,     # (B, cap) i32 labels, 0 = empty/padding
    *,
    use_pallas: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-direction consistent-threshold intervals (lo, hi), each (B, m);
    a missing class yields -inf/+inf."""
    if use_pallas:
        lo, hi = ops.support_ranges_batch(V, Wx, Wy)
        lo = jnp.where(lo <= -BIG / 2, -jnp.inf, lo)
        hi = jnp.where(hi >= BIG / 2, jnp.inf, hi)
    else:
        lo, hi = ref.threshold_ranges_batch_ref(V, Wx, Wy)
    return lo, hi


def median_cut(
    V: jnp.ndarray,       # (m, d)
    dir_ok: jnp.ndarray,  # (B, m) bool
    lo: jnp.ndarray,      # (B, m)
    hi: jnp.ndarray,      # (B, m)
    X: jnp.ndarray,       # (B, n, d)
    y: jnp.ndarray,       # (B, n) i32, 0 = padding
    *,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched median-cut scores (int32 (B, m), -1 at disallowed cuts): the
    (B, m, n) weighted-median scan the MEDIAN coordinator argmaxes.  On TPU
    this is the fused ``kernels.median_cut`` Pallas kernel — one pallas_call
    for the whole sweep, never materializing the (B, m, n) risk tensor in
    HBM; elsewhere the jitted vmap reference.  Both produce identical
    integer scores (bit-for-bit, tested)."""
    use_pallas = use_pallas_default() if use_pallas is None else use_pallas
    if use_pallas:
        return ops.support_median_cut_batch(
            V, dir_ok.astype(jnp.float32), lo, hi, X, y)
    return ref.median_cut_scores_batch_ref(V, dir_ok, lo, hi, X, y)


def median_extremes(
    v: jnp.ndarray,       # (B, d) per-instance proposed directions
    XW: jnp.ndarray,      # (B, k, nW, d) own ∪ fill-capped transcripts
    yW: jnp.ndarray,      # (B, k, nW) i32/f32, 0 = padding
    *,
    use_pallas: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched per-node extreme-point indices along v — MEDIAN's stage-5
    per-turn scan at the hot loop's fill-capped width.  On TPU the fused
    ``kernels.support_margin.median_extremes_batched`` Pallas kernel, else
    the jitted vmap reference; identical integer row choices (bit-for-bit,
    tested in tests/test_kernels_interpret.py)."""
    use_pallas = use_pallas_default() if use_pallas is None else use_pallas
    if use_pallas:
        return ops.support_extremes_batch(v, XW, yW)
    return ref.median_extremes_batch_ref(v, XW, yW)


def uncertain(
    V: jnp.ndarray,       # (m, d)
    dir_ok: jnp.ndarray,  # (B, m) bool
    lo: jnp.ndarray,      # (B, m)
    hi: jnp.ndarray,      # (B, m)
    X: jnp.ndarray,       # (B, n, d)
    y: jnp.ndarray,       # (B, n) i32, 0 = padding
    *,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched SOU membership, bool (B, n); padding rows report False."""
    use_pallas = use_pallas_default() if use_pallas is None else use_pallas
    if use_pallas:
        mask = ops.support_uncertain_batch(V, dir_ok, lo, hi, X, y)
    else:
        mask = ref.uncertain_mask_batch_ref(V, dir_ok, lo, hi, X, y)
    return mask & (y != 0)
