"""Mixed-selector protocol turns over one superset state (the tentpole of
DESIGN.md §unified mixed-selector state).

``run_sweep`` historically bucketed a heterogeneous grid by selector and
compiled one dispatch per bucket — fine for paper grids, wrong for a
production mix where MEDIAN, MAXMARG and one-way SAMPLING sessions
interleave and a session pool must admit any of them into any freed slot.
This module is the one-dispatch answer: a single jitted ``step`` over
:class:`~repro.engine.state.UnifiedState` whose per-instance selector code
is *data* (a traced (B,) i32 leaf), so the compile-cache key never depends
on the traffic mix.

**Masked substeps, not ``lax.switch``.**  The turn body runs every
family's substep over the shared leaves and merges per-row by selector
mask:

* the MEDIAN substep is :func:`repro.engine.median.step` on a view whose
  ``done`` masks every non-MEDIAN row (statically omitted when the mix has
  no median rows);
* the MAXMARG substep is :func:`repro.engine.maxmarg.step` on a view
  masking MEDIAN rows and pre-fit SAMPLING rows — a SAMPLING row *rides
  the MAXMARG fit*: its Vitter reservoir lives in node ``k-1``'s
  transcript, so at its fit turn (``turn ≥ k-1``, where the coordinator
  index is exactly ``k-1``) the MAXMARG fit set ``own ∪ transcript`` *is*
  the sampling oracle's ``X[k-1] ∪ reservoir`` fit, and the proposal lands
  in the shared separator leaves;
* the SAMPLING hop substep reuses :func:`repro.engine.oneway._make_ingest`
  (vmapped, bitwise the one-way oracle's Vitter process) on the reservoir
  slice of the shared transcript and meters the oracle's per-hop comm.

``lax.switch`` would buy nothing here: with a *batched* predicate a
vmapped switch lowers to select-over-all-branches — every branch executes
for every row anyway — so the masked form pays the same compute with none
of the branch-plumbing, and keeps each family's substep byte-identical to
its single-selector oracle (the DESIGN.md tradeoff; measured in
BENCH_service.json's ``mixed_traffic`` series).  Each family's substep
writes are discarded row-wise by the merge wherever another family owns
the row, so per-row results match the per-selector paths: MEDIAN rows
bit-exact (any covering transcript width is), MAXMARG and SAMPLING rows
decision/comm-exact with separators equal up to the float reassociation of
padded solver widths (tests/test_unified.py pins all three).

Compile-key contract: ``step``'s cache keys on the static tuple
(`k`, `max_support`, `steps`, `stages`, `trans_width`, `warm`,
`per_node`, `has_median`, `first_turn`, kernel flags) plus the leaf
shapes (B, cap, n_max, m) — *never* on the selector mix, the admission
order, or any per-row value.  ``hotloop.run_hot`` drives it at
geometric width buckets by default so mixed-width traffic stays within
O(log cap) compiled variants.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.engine import hotloop, median, oneway
from repro.engine import maxmarg as mm
from repro.engine.state import (
    EngineData,
    MaxMargState,
    ProtocolInstance,
    ProtocolState,
    SEL_MAXMARG,
    SEL_MEDIAN,
    SEL_SAMPLING,
    UnifiedState,
    pack_instances_unified,
)


def _median_view(state: UnifiedState) -> ProtocolState:
    """The MEDIAN substep's input: shared leaves aliased (h_v/h_t live in
    the shared h_w/h_b), every non-MEDIAN row masked done."""
    return ProtocolState(
        dir_ok=state.dir_ok, wx=state.wx, wy=state.wy, w_fill=state.w_fill,
        lo_w=state.lo_w, hi_w=state.hi_w, turn=state.turn,
        done=state.done | (state.sel != SEL_MEDIAN),
        converged=state.converged, epochs=state.epochs,
        h_v=state.h_w, h_t=state.h_b, h_valid=state.h_valid,
        comm=state.comm)


def _maxmarg_view(state: UnifiedState, k: int) -> MaxMargState:
    """The MAXMARG substep's input: MEDIAN rows masked done, SAMPLING rows
    masked until their fit turn (``turn ≥ k-1``, when the coordinator is
    node k-1 and the fit set equals the sampling oracle's)."""
    pre_fit = (state.sel == SEL_SAMPLING) & (state.turn < k - 1)
    return MaxMargState(
        wx=state.wx, wy=state.wy, w_fill=state.w_fill, turn=state.turn,
        done=state.done | (state.sel == SEL_MEDIAN) | pre_fit,
        converged=state.converged, epochs=state.epochs,
        h_w=state.h_w, h_b=state.h_b, h_valid=state.h_valid,
        warm_turn=state.warm_turn, c_w=state.c_w, c_b=state.c_b,
        c_valid=state.c_valid, warm_node=state.warm_node,
        latches=state.latches, comm=state.comm)


def _bc(mask: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1))


def step(
    data: EngineData,
    V: jnp.ndarray,
    state: UnifiedState,
    *,
    k: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
    trans_width: Optional[int] = None,
    warm: bool = False,
    per_node: bool = True,
    has_median: bool = True,
    first_turn: bool = False,
    cut_kernel: bool = False,
    extremes_kernel: bool = False,
    fused_kernel: bool = False,
    solver_kernel: Optional[bool] = None,
) -> UnifiedState:
    """Advance every active instance by one turn of *its own* protocol
    (pure, jittable, shape-stable).

    Statics are the union of the per-selector steps' plus ``has_median``
    (which omits the MEDIAN substep entirely for median-free mixes — the
    stub 1-wide arc leaves then pass through untouched).  ``trans_width``
    caps every transcript read exactly like the per-selector steps, and
    additionally bounds the SAMPLING reservoir slice — the hot loop's width
    must cover every live SAMPLING row's ``res_cap`` (``_host_view`` folds
    ``res_cap`` into the fill row to guarantee it; reservoir writes beyond
    the static slice would be silently dropped otherwise).

    Per-row masking discipline: each substep sees a view whose ``done``
    masks every row it does not own, and the merge takes each leaf from its
    owning family only — a substep's masked scratch writes (label-0 append
    rows, solver proposals on foreign rows) are discarded wholesale, so
    every row's trajectory is the one its single-selector oracle computes.
    """
    is_med = state.sel == SEL_MEDIAN
    is_mm = state.sel == SEL_MAXMARG
    is_samp = state.sel == SEL_SAMPLING
    active = ~state.done

    # -- family substeps over the shared leaves -----------------------------
    med = None
    if has_median:
        med = median.step(
            data, V, _median_view(state), k=k, first_turn=first_turn,
            cut_kernel=cut_kernel, extremes_kernel=extremes_kernel,
            trans_width=trans_width)
    mmo = mm.step(
        data, _maxmarg_view(state, k), k=k, max_support=max_support,
        steps=steps, stages=stages, lam0=lam0, trans_width=trans_width,
        warm=warm, per_node=per_node, fused_kernel=fused_kernel,
        solver_kernel=solver_kernel)

    # -- sampling hop substep (the oracle's Vitter chain, one hop per turn) -
    hop_act = active & is_samp & (state.turn < k - 1)
    fit_act = active & is_samp & (state.turn >= k - 1)
    hop_t = jnp.clip(state.turn, 0, max(k - 2, 0))
    res_w = int(state.wx.shape[2]) if trans_width is None else trans_width
    Xi = hotloop.gather_rows(data.X, hop_t)              # (B, n_max, d)
    yi = hotloop.gather_rows(data.y, hop_t)
    keyb = hotloop.gather_rows(state.hop_keys, hop_t)    # (B, 2) u32
    resX = state.wx[:, k - 1, :res_w]
    resy = state.wy[:, k - 1, :res_w]
    rX, ry, sn = jax.vmap(oneway._make_ingest(res_w))(
        resX, resy, state.seen, keyb, Xi, yi, state.res_cap)
    shipped = jnp.minimum(sn, state.res_cap)
    wx_s = state.wx.at[:, k - 1, :res_w].set(
        jnp.where(_bc(hop_act, rX), rX, resX))
    wy_s = state.wy.at[:, k - 1, :res_w].set(
        jnp.where(hop_act[:, None], ry, resy))
    w_fill_s = state.w_fill.at[:, k - 1].set(
        jnp.where(hop_act, shipped, state.w_fill[:, k - 1]))
    # the oracle's per-hop message slot: the forwarded reservoir (possibly
    # empty — still one message), one round per hop; nothing at the fit turn
    comm_s = state.comm._replace(
        points=state.comm.points + jnp.where(hop_act, shipped, 0),
        messages=state.comm.messages + hop_act.astype(jnp.int32),
        rounds=state.comm.rounds + hop_act.astype(jnp.int32))

    # -- per-row merge: each leaf from its owning family --------------------
    def pick(med_leaf, mm_leaf, samp_leaf):
        out = jnp.where(_bc(is_mm, samp_leaf), mm_leaf, samp_leaf)
        if med is not None:
            out = jnp.where(_bc(is_med, out), med_leaf, out)
        return out

    m_ = med if med is not None else mmo  # unread when has_median is False
    return UnifiedState(
        sel=state.sel,
        dir_ok=m_.dir_ok if med is not None else state.dir_ok,
        lo_w=m_.lo_w if med is not None else state.lo_w,
        hi_w=m_.hi_w if med is not None else state.hi_w,
        wx=pick(m_.wx, mmo.wx, wx_s),
        wy=pick(m_.wy, mmo.wy, wy_s),
        w_fill=pick(m_.w_fill, mmo.w_fill, w_fill_s),
        turn=state.turn + 1,
        done=pick(m_.done, mmo.done, state.done | fit_act),
        converged=pick(m_.converged, mmo.converged,
                       state.converged | fit_act),
        epochs=pick(m_.epochs, mmo.epochs,
                    jnp.where(fit_act, k - 1, state.epochs)),
        h_w=jnp.where(_bc(is_med, state.h_w), m_.h_v, mmo.h_w)
        if med is not None else mmo.h_w,
        h_b=jnp.where(is_med, m_.h_t, mmo.h_b)
        if med is not None else mmo.h_b,
        h_valid=jnp.where(is_med, m_.h_valid, mmo.h_valid)
        if med is not None else mmo.h_valid,
        warm_turn=mmo.warm_turn, c_w=mmo.c_w, c_b=mmo.c_b,
        c_valid=mmo.c_valid, warm_node=mmo.warm_node, latches=mmo.latches,
        seen=jnp.where(hop_act, sn, state.seen),
        res_cap=state.res_cap,
        hop_keys=state.hop_keys,
        comm=type(state.comm)(*(pick(a, b, c) for a, b, c in
                                zip(m_.comm if med is not None else comm_s,
                                    mmo.comm, comm_s))),
    )


_STEP_STATICS = ("k", "max_support", "steps", "stages", "trans_width",
                 "warm", "per_node", "has_median", "first_turn",
                 "cut_kernel", "extremes_kernel", "fused_kernel",
                 "solver_kernel")

_step_jit = jax.jit(step, static_argnames=_STEP_STATICS)


def _pad_fix(sub: UnifiedState, pad_row: jnp.ndarray) -> UnifiedState:
    """Mark gathered out-of-range rows inert: done=True masks them out of
    every substep's decisions, and trusting their (zero) carries keeps the
    warm polish gate from ever forcing solver work for padding (same
    contract as the per-selector pad fixes; pad rows gather ``sel=0``,
    which is harmless under ``done``)."""
    return sub._replace(done=sub.done | pad_row,
                        h_valid=sub.h_valid | pad_row,
                        c_valid=sub.c_valid | pad_row[:, None],
                        warm_node=sub.warm_node | pad_row[:, None])


def _hot_turn_impl(
    data: EngineData,
    V: jnp.ndarray,
    state: UnifiedState,
    idx: jnp.ndarray,       # (n_pad,) i32 — active rows, tail = B (dropped)
    n_act: jnp.ndarray,     # () i32 — live prefix of idx
    *,
    k: int,
    max_support: int,
    steps: int,
    stages: int,
    lam0: float,
    trans_width: int,
    warm: bool,
    per_node: bool,
    has_median: bool,
    first_turn: bool,
    cut_kernel: bool,
    extremes_kernel: bool,
    fused_kernel: bool,
    solver_kernel: Optional[bool] = None,
) -> UnifiedState:
    """One compacted mixed turn as a single dispatch (gather → pad-fix →
    step → scatter, ``hotloop.gathered_turn``); V passes through ungathered
    like the MEDIAN hot turn."""
    step_fn = functools.partial(
        step, k=k, max_support=max_support, steps=steps, stages=stages,
        lam0=lam0, trans_width=trans_width, warm=warm, per_node=per_node,
        has_median=has_median, first_turn=first_turn, cut_kernel=cut_kernel,
        extremes_kernel=extremes_kernel, fused_kernel=fused_kernel,
        solver_kernel=solver_kernel)
    return hotloop.gathered_turn(
        lambda sub_data, sub: step_fn(sub_data, V, sub),
        _pad_fix, data, state, idx, n_act)


_hot_turn = jax.jit(_hot_turn_impl, static_argnames=_STEP_STATICS)


@functools.partial(jax.jit, static_argnames=("per_node",))
def _host_view(state: UnifiedState, ci: jnp.ndarray, *,
               per_node: bool = True) -> jnp.ndarray:
    """The hot loop's per-turn host knowledge as one (3, B) i32 transfer:
    done flags, warm-latch flags (MAXMARG rows only — the other families
    have no warm carry, so they can never force a warm-keyed dispatch),
    and the width-compaction fills.  Fills are the per-row max across
    nodes, and for SAMPLING rows additionally at least ``res_cap``: the
    compacted width bounds the reservoir slice, and an ingest write beyond
    it would be silently scatter-dropped — covering ``res_cap`` keeps the
    reservoir bitwise the oracle's at every width the loop can pick."""
    k = state.w_fill.shape[1]
    track = per_node and k > 2
    wflag = (jnp.take(state.warm_node, ci, axis=1) if track
             else state.warm_turn)
    wflag = wflag & (state.sel == SEL_MAXMARG)
    fills = jnp.max(state.w_fill, axis=1)
    fills = jnp.where(state.sel == SEL_SAMPLING,
                      jnp.maximum(fills, state.res_cap), fills)
    return jnp.stack([state.done.astype(jnp.int32),
                      wflag.astype(jnp.int32),
                      fills])


def run_hot(
    data: EngineData,
    V: jnp.ndarray,
    state: UnifiedState,
    *,
    k: int,
    max_turns: int,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam0: float = 1e-3,
    warm: bool = True,
    per_node: bool = True,
    has_median: bool = True,
    compact: bool = True,
    cut_kernel: bool = False,
    extremes_kernel: bool = False,
    fused_kernel: bool = False,
    solver_kernel: Optional[bool] = None,
    width_policy: str = "geometric",
    stats: Optional[dict] = None,
) -> UnifiedState:
    """The mixed sweep as a host-driven turn loop over the jitted ``step``
    (the shared machinery in :mod:`repro.engine.hotloop`).

    One loop drives all three families at once: the width slack and the
    stale-view growth bound are the *max* over the families' own bounds
    (MEDIAN's post-S extremes slack, MAXMARG's support/violation appends),
    so every compacted read covers whichever family's transcript grew
    fastest.  ``width_policy`` defaults to ``"geometric"`` here — mixed
    traffic spreads live fills across families with very different growth
    rates, exactly the churn case the geometric buckets bound — while the
    per-selector loops keep their linear (byte-identical legacy) rule.
    """
    cap = int(state.wx.shape[2])
    track = per_node and warm
    opts = dict(k=k, max_support=max_support, steps=steps, stages=stages,
                lam0=lam0, per_node=track, has_median=has_median,
                cut_kernel=cut_kernel, extremes_kernel=extremes_kernel,
                fused_kernel=fused_kernel, solver_kernel=solver_kernel)
    width_slack = median.WIDTH_SLACK if has_median else 0
    width_growth = max(2 * k + 2, max_support, mm.VIOL_SHIP * (k - 1))

    def host_view(s, ci):
        return _host_view(s, ci, per_node=track)

    def dispatch_full(s, *, t, width, use_warm):
        return _step_jit(data, V, s, first_turn=(t == 0),
                         trans_width=width, warm=use_warm, **opts)

    def dispatch_sub(s, idx, n_act, *, t, width, use_warm):
        return _hot_turn(data, V, s, idx, n_act, first_turn=(t == 0),
                         trans_width=width, warm=use_warm, **opts)

    return hotloop.run_hot(state, k=k, max_turns=max_turns, cap=cap,
                           host_view=host_view,
                           dispatch_full=dispatch_full,
                           dispatch_sub=dispatch_sub, warm=warm,
                           compact=compact, width_slack=width_slack,
                           width_growth=width_growth,
                           width_policy=width_policy, stats=stats)


def run_instances(
    instances: Sequence[ProtocolInstance],
    *,
    eps: Optional[float] = None,
    n_angles: int = 1024,
    max_epochs: int = 48,
    max_support: int = 4,
    steps: int = 2000,
    stages: int = 3,
    lam: float = 1e-3,
    warm: bool = True,
    per_node: bool = True,
    compact: bool = True,
    vc_dim: Optional[int] = None,
    c: Optional[float] = None,
    solver_kernel: Optional[bool] = None,
    width_policy: str = "geometric",
    stats: Optional[dict] = None,
):
    """Run a mixed MEDIAN + MAXMARG + SAMPLING grid as ONE compiled
    dispatch path — no selector bucketing.

    Returns :class:`~repro.core.protocols.one_way.ProtocolResult` per
    instance in input order, shaped exactly like the per-selector
    ``run_instances`` paths' (which survive unchanged as this path's
    differential oracles): MEDIAN rows recover ``LinearSeparator(-h_v,
    h_t)`` from the shared separator leaves, MAXMARG rows report their
    warm-latch count, SAMPLING rows their ε-net ``sample_size`` with
    ``rounds = k-1`` and ``converged=True``.

    Compile-key contract: the compiled step variants key on the static
    solver/protocol options and the compacted (n_pad, width, warm) shapes
    — never on the selector mix, so any interleaving of families at equal
    shapes reuses one cache (tests/test_recompile.py's mixed gate).
    Options that a family does not use are simply inert for its rows
    (``n_angles`` for MAXMARG, ``vc_dim``/``c`` for MEDIAN, …).
    """
    from repro.core import classifiers as clf
    from repro.core import geometry as geo
    from repro.core.protocols.one_way import ProtocolResult

    if eps is not None:
        instances = [ProtocolInstance(inst.shards, eps, inst.selector,
                                      inst.seed) for inst in instances]
    data, state0, k, _cap = pack_instances_unified(
        instances, n_angles=n_angles, max_epochs=max_epochs,
        max_support=max_support, vc_dim=vc_dim, c=c)
    d = int(data.X.shape[3])
    has_median = any(inst.selector == "median" for inst in instances)
    if has_median:
        V = jnp.asarray(geo.direction_grid(n_angles), jnp.float32)
    else:
        V = jnp.zeros((1, d), jnp.float32)
    final = run_hot(data, V, state0, k=k, max_turns=k * max_epochs,
                    max_support=max_support, steps=steps, stages=stages,
                    lam0=lam, warm=warm, per_node=per_node,
                    has_median=has_median, compact=compact,
                    solver_kernel=solver_kernel, width_policy=width_policy,
                    stats=stats)

    converged = np.asarray(final.converged)
    epochs = np.asarray(final.epochs)
    h_w = np.asarray(final.h_w, np.float64)
    h_b = np.asarray(final.h_b, np.float64)
    latches = np.asarray(final.latches)
    res_cap = np.asarray(final.res_cap)
    comm_np = type(final.comm)(*(np.asarray(a) for a in final.comm))
    extra = {"engine": True, "batch": len(instances), "unified": True,
             "warm": warm, "compact": compact}
    results: List[ProtocolResult] = []
    for b, inst in enumerate(instances):
        ex = dict(extra, selector=inst.selector)
        if inst.selector == "median":
            h = clf.LinearSeparator(-h_w[b], float(h_b[b]))
            rounds = int(epochs[b]) if converged[b] else max_epochs
            conv = bool(converged[b])
        elif inst.selector == "maxmarg":
            h = clf.LinearSeparator(h_w[b], float(h_b[b]))
            rounds = int(epochs[b]) if converged[b] else max_epochs
            conv = bool(converged[b])
            ex["warm_latches"] = int(latches[b])
        else:
            h = clf.LinearSeparator(h_w[b], float(h_b[b]))
            rounds = k - 1
            conv = True
            ex["sample_size"] = int(res_cap[b])
        results.append(ProtocolResult(
            h, comm_np.summary(b, dim=d), rounds=rounds, converged=conv,
            extra=ex))
    return results
