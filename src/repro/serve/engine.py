"""Token-decode stub (NOT the protocol service).

This module is the seed's generic LLM-decode scaffolding — a jit'd
``serve_step`` (one token, batched requests) plus a minimal greedy host
engine over ``repro.models``.  It exists so the decode-shape dry-runs have
something to lower; it has nothing to do with serving the paper's
classifier protocols.

The *protocol* serving entry point is :class:`repro.serve.service.\
ProtocolService` — streaming ingest over the fault-tolerant session pool
(``repro.engine.session_pool``).  Use that unless you specifically want
the token decoder, which now lives under its explicit name
:class:`TokenServingEngine` (``ServingEngine`` remains as a compatibility
alias).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import RunFlags, decode_step, make_caches, prefill


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    cache_len: int
    dtype: Any = jnp.bfloat16
    flags: RunFlags = RunFlags()
    enc_len: int = 0
    temperature: float = 0.0  # greedy


def make_serve_step(cfg: ModelConfig, sc: ServeConfig) -> Callable:
    """Pure (params, caches, tokens (B,1), pos ()) -> (logits, caches)."""

    def serve_step(params, caches, tokens, pos):
        return decode_step(params, cfg, caches, tokens, pos, sc.flags, dtype=sc.dtype)

    return serve_step


class TokenServingEngine:
    """Minimal batched greedy decoder over the functional model API.

    Explicitly the token-decode stub — see the module docstring; protocol
    sessions are served by ``repro.serve.service.ProtocolService``.
    """

    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig, jit: bool = True):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.caches = make_caches(cfg, sc.batch, sc.cache_len, sc.dtype,
                                  enc_len=sc.enc_len)
        step = make_serve_step(cfg, sc)
        self.step = jax.jit(step, donate_argnums=(1,)) if jit else step
        self.prefill_fn = jax.jit(
            lambda p, b, c: prefill(p, cfg, b, c, sc.flags, dtype=sc.dtype)) if jit else (
            lambda p, b, c: prefill(p, cfg, b, c, sc.flags, dtype=sc.dtype))
        self.pos = 0

    def prefill_prompt(self, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        logits, self.caches = self.prefill_fn(self.params, batch, self.caches)
        self.pos = batch["tokens"].shape[1]
        return logits

    def generate(self, first_token: jnp.ndarray, n_tokens: int) -> np.ndarray:
        """Greedy-decode ``n_tokens`` for every request in the batch."""
        tok = first_token.reshape(self.sc.batch, 1).astype(jnp.int32)
        out: List[np.ndarray] = []
        for _ in range(n_tokens):
            logits, self.caches = self.step(self.params, self.caches, tok,
                                            jnp.int32(self.pos))
            tok = logits[:, -1, :].argmax(-1).astype(jnp.int32).reshape(-1, 1)
            out.append(np.asarray(tok))
            self.pos += 1
        return np.concatenate(out, axis=1)


# Compatibility alias: the decode stub shipped under this generic name
# before the protocol service took over the package's front door.
ServingEngine = TokenServingEngine
