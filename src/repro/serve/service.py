"""Protocol serving: the streaming front end over the fault-tolerant
session pool.

This is the primary entry point of :mod:`repro.serve` (the ROADMAP's
persistent-service north star): callers open sessions, stream labeled
points per node, close the session to enqueue it, and pump the pool —
while :mod:`repro.engine.session_pool` handles admission into freed slots
at pinned compile-cache keys, seeded fault injection, retry/backoff
supervision and checkpoint/restore underneath.

Ingest is reservoir-based (``core.sampling.Reservoir.add_batch``): each
node of an open session downsamples its stream into a reservoir of
capacity ≤ the pool's pinned ``n_pad``, and :meth:`ProtocolService.close`
takes the reservoir snapshot as that node's shard (the pool pads it to the
pinned shape with inert label-0 rows) — so unbounded streams admit at
bounded, shape-stable cost, and the reservoir's Vitter inclusion
probabilities are the paper's one-way sampling semantics.
Callers with ready-made shards can skip the stream and :meth:`submit`
directly.

The token-decode stub this package's seed shipped lives on as
``repro.serve.engine.TokenServingEngine`` — unrelated to protocol serving.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sampling import Reservoir
from repro.engine.faults import FaultSchedule
from repro.engine.session_pool import PoolConfig, SessionPool


@dataclasses.dataclass
class _OpenSession:
    reservoirs: List[Reservoir]
    eps: Optional[float]
    selector: Optional[str] = None
    seed: int = 0


class ProtocolService:
    """Streaming protocol service: reservoir ingest → session pool.

    ::

        svc = ProtocolService(PoolConfig(slots=32, k=2, n_pad=64),
                              schedule=FaultSchedule(seed=7, p_dropout=0.05))
        h = svc.open()
        svc.feed(h, node=0, X=batch0, y=labels0)   # any number of batches
        svc.feed(h, node=1, X=batch1, y=labels1)
        sid = svc.close(h)                          # enqueue for admission
        svc.run()                                   # drain the pool
        svc.result(sid)                             # ProtocolResult
        svc.status(sid), svc.stats                  # supervision surface

    The service adds no decision logic of its own: every admission,
    dispatch, fault, retry and eviction decision is the pool's, so the
    pool's determinism and bit-exactness contracts carry over verbatim
    (same workload + config + schedule ⇒ same decisions, including across
    :meth:`checkpoint` / :meth:`restore`).  On a
    ``PoolConfig(selector="unified")`` pool, :meth:`open` and :meth:`submit`
    take a per-session ``selector`` (and Vitter ``seed``), so one service
    instance absorbs heterogeneous MEDIAN / MAXMARG / SAMPLING traffic.

    Compile-key contract (inherited from the pool): every compiled variant
    is keyed by ``PoolConfig`` alone — geometry (``slots``/``k``/``n_pad``/
    ``d``), transcript ``cap``, solver statics and scatter block shapes.
    Nothing streamed through this API (batch sizes fed per node, session
    count, ε, selector mix, seeds, admission order) ever recompiles;
    per-node stream length is decoupled from the pinned shapes by the
    reservoir, which downsamples any stream to ≤ ``n_pad`` rows.
    """

    def __init__(self, config: PoolConfig,
                 schedule: Optional[FaultSchedule] = None,
                 ingest_seed: int = 0):
        self.pool = SessionPool(config, schedule)
        self.cfg = config
        self._ingest_seed = ingest_seed
        self._open: Dict[int, _OpenSession] = {}
        self._next_handle = 0

    # -- streaming ingest ---------------------------------------------------

    def open(self, eps: Optional[float] = None,
             reservoir_capacity: Optional[int] = None,
             selector: Optional[str] = None, seed: int = 0) -> int:
        """Open a streaming session: one reservoir per node, capacity
        ``reservoir_capacity`` (default: the pool's pinned ``n_pad``).
        ``selector``/``seed`` tag the session's protocol family on unified
        pools (validated at :meth:`close`, when the pool sees them).
        Returns an ingest handle (not yet a pool session id)."""
        cap = self.cfg.n_pad if reservoir_capacity is None \
            else reservoir_capacity
        if cap > self.cfg.n_pad:
            raise ValueError(
                f"reservoir capacity {cap} exceeds pinned n_pad="
                f"{self.cfg.n_pad}")
        h = self._next_handle
        self._next_handle += 1
        self._open[h] = _OpenSession(
            reservoirs=[
                Reservoir(cap, self.cfg.d,
                          rng=np.random.default_rng(
                              (self._ingest_seed, h, node)))
                for node in range(self.cfg.k)],
            eps=eps, selector=selector, seed=seed)
        return h

    def feed(self, handle: int, node: int, X: np.ndarray,
             y: np.ndarray) -> None:
        """Stream a labeled batch into one node's reservoir
        (``Reservoir.add_batch`` — vectorized Vitter)."""
        sess = self._open[handle]
        if not 0 <= node < self.cfg.k:
            raise ValueError(f"node {node} outside 0..{self.cfg.k - 1}")
        sess.reservoirs[node].add_batch(X, y)

    def close(self, handle: int) -> int:
        """Finalize a streaming session: take each node's reservoir snapshot
        (the filled rows only — the pool pads to its pinned ``n_pad`` with
        inert label-0 rows, keeping the error budget on real points) and
        enqueue the instance for admission.  Returns the pool session id."""
        sess = self._open.pop(handle)
        shards = []
        for r in sess.reservoirs:
            if r.filled == 0:
                raise ValueError("cannot close a session with an empty node")
            shards.append(r.sample())
        return self.pool.submit(shards, eps=sess.eps,
                                selector=sess.selector, seed=sess.seed)

    def submit(self, shards: Sequence[Tuple[np.ndarray, np.ndarray]],
               eps: Optional[float] = None,
               selector: Optional[str] = None, seed: int = 0) -> int:
        """Enqueue ready-made shards directly (no streaming)."""
        return self.pool.submit(shards, eps=eps, selector=selector,
                                seed=seed)

    # -- pool pump ----------------------------------------------------------

    def step(self) -> None:
        """Advance the pool by one turn (admission → dispatch → screen)."""
        self.pool.step_pool()

    def run(self, max_pool_turns: Optional[int] = None) -> Dict[int, Any]:
        """Drain every enqueued session to a terminal status."""
        return self.pool.run(max_pool_turns)

    # -- results & supervision surface --------------------------------------

    def result(self, sid: int):
        return self.pool.results.get(sid)

    def status(self, sid: int) -> str:
        return self.pool.sessions[sid]["status"]

    def session(self, sid: int) -> Dict[str, Any]:
        return self.pool.sessions[sid]

    @property
    def stats(self) -> Dict[str, Any]:
        return self.pool.stats

    # -- persistence --------------------------------------------------------

    def checkpoint(self, dirname: str) -> str:
        """Snapshot the pool (open ingest handles are host-side reservoirs
        and are NOT captured — close them first; enqueued and live sessions
        round-trip bit-exact)."""
        if self._open:
            raise RuntimeError(
                f"{len(self._open)} ingest session(s) still open; close "
                "them before checkpointing (reservoir RNG state is not "
                "snapshotted)")
        return self.pool.checkpoint(dirname)

    @classmethod
    def restore(cls, dirname: str) -> "ProtocolService":
        svc = cls.__new__(cls)
        svc.pool = SessionPool.restore(dirname)
        svc.cfg = svc.pool.cfg
        svc._ingest_seed = 0
        svc._open = {}
        svc._next_handle = 0
        return svc
