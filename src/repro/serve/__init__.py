"""Serving package.  Primary entry point: :class:`ProtocolService` —
streaming protocol sessions over the fault-tolerant session pool.  The
token-decode stub keeps its old names available for the decode dry-runs.
"""

from repro.serve.service import ProtocolService  # noqa: F401
from repro.engine.session_pool import PoolConfig  # noqa: F401
from repro.engine.faults import FAULT_FREE, FaultSchedule  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    ServeConfig,
    ServingEngine,
    TokenServingEngine,
    make_serve_step,
)
