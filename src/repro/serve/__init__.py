from repro.serve.engine import ServeConfig, ServingEngine, make_serve_step  # noqa: F401
