"""Training loop: jit'd train_step (loss + grad + AdamW) and a host driver.

``make_train_step`` is the function the multi-pod dry-run lowers — it takes
(params, opt_state, batch) and returns (params, opt_state, metrics), pure and
donate-safe.  The ``Trainer`` adds the host-side loop: data, logging,
checkpoints, eval.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import RunFlags, forward_train, init_lm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    warmup: int = 20
    log_every: int = 10
    ckpt_every: int = 0          # 0 = only at end
    ckpt_dir: Optional[str] = None
    seed: int = 0
    dtype: Any = jnp.bfloat16
    microbatches: int = 1        # gradient accumulation (activation memory ÷ mb)
    optim: AdamWConfig = AdamWConfig()
    flags: RunFlags = RunFlags()


def _split_micro(batch: Dict, mb: int) -> Dict:
    """(B, ...) leaves -> (mb, B/mb, ...); rope_pos has batch at axis 1."""
    def f(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        bdim = 1 if name == "rope_pos" else 0
        B = leaf.shape[bdim]
        assert B % mb == 0, (name, B, mb)
        new = leaf.shape[:bdim] + (mb, B // mb) + leaf.shape[bdim + 1:]
        out = leaf.reshape(new)
        if bdim != 0:
            out = jnp.moveaxis(out, bdim, 0)
        return out
    return {k: f((jax.tree_util.DictKey(k),), v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Pure (params, opt_state, batch) -> (params, opt_state, metrics).

    With ``microbatches > 1`` the loss/grad pass runs as a rematerialized
    ``lax.scan`` over microbatches, accumulating f32 grads — activation
    footprint scales with the microbatch, not the global batch.
    """

    def loss_fn(p, batch):
        loss, metrics = forward_train(p, cfg, batch, tc.flags, dtype=tc.dtype)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if tc.microbatches > 1:
            micro = _split_micro(batch, tc.microbatches)

            def acc(carry, mbatch):
                g_acc, l_acc, a_acc = carry
                (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss, a_acc + metrics["acc"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, acc_sum), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            loss = loss / tc.microbatches
            metrics = {"acc": acc_sum / tc.microbatches}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr_scale = cosine_schedule(opt_state["step"], tc.warmup, tc.steps)
        params, opt_state, om = adamw_update(tc.optim, params, grads, opt_state, lr_scale)
        metrics = dict(metrics, loss=loss, lr_scale=lr_scale, **om)
        return params, opt_state, metrics

    return train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig, data: Iterator[Dict],
                 params=None, jit: bool = True):
        self.cfg, self.tc, self.data = cfg, tc, data
        key = jax.random.PRNGKey(tc.seed)
        self.params = params if params is not None else init_lm(key, cfg, jnp.float32)
        self.opt_state = adamw_init(self.params, tc.optim.moment_dtype)
        step_fn = make_train_step(cfg, tc)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1)) if jit else step_fn
        self.history = []

    def run(self, steps: Optional[int] = None) -> Dict[str, float]:
        steps = steps or self.tc.steps
        t0 = time.time()
        last = {}
        for i in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(self.data).items()}
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if i % self.tc.log_every == 0 or i == steps - 1:
                last = {k: float(v) for k, v in metrics.items()}
                last["step"] = i
                last["wall_s"] = time.time() - t0
                self.history.append(last)
                print(f"step {i:5d} loss {last['loss']:.4f} acc {last.get('acc', 0):.3f} "
                      f"gnorm {last['grad_norm']:.3f} ({last['wall_s']:.1f}s)")
        if self.tc.ckpt_dir:
            from repro.train.checkpoint import save_checkpoint
            save_checkpoint(self.tc.ckpt_dir, self.params, self.opt_state,
                            step=int(self.opt_state["step"]))
        return last
