"""Checkpointing: flat-key .npz snapshots of arbitrary pytrees.

No external deps (orbax not available offline); keys are '/'-joined tree
paths, values numpy arrays, plus a JSON treedef manifest for exact restore.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(dirname: str, params, opt_state=None, step: int = 0) -> str:
    os.makedirs(dirname, exist_ok=True)
    payload = {"params": params}
    if opt_state is not None:
        payload["opt"] = opt_state
    flat = _flatten(payload)
    path = os.path.join(dirname, f"ckpt_{step:08d}.npz")
    np.savez(path, **flat)
    with open(os.path.join(dirname, "latest.json"), "w") as f:
        json.dump({"path": path, "step": step}, f)
    return path


def load_checkpoint(dirname: str, like=None) -> Tuple[Any, Optional[Any], int]:
    """Returns (params, opt_state, step); ``like`` restores exact structure."""
    with open(os.path.join(dirname, "latest.json")) as f:
        meta = json.load(f)
    data = np.load(meta["path"])
    if like is None:
        # nested dict reconstruction from flat keys
        out: Dict[str, Any] = {}
        for k in data.files:
            parts = k.split("/")
            d = out
            for pp in parts[:-1]:
                d = d.setdefault(pp, {})
            d[parts[-1]] = data[k]
        return out.get("params", out), out.get("opt"), meta["step"]
    flat_like = _flatten({"params": like})
    restored = {k: data[k] for k in flat_like}
    leaves, treedef = jax.tree.flatten({"params": like})
    keys = [
        "/".join(str(getattr(kk, "key", getattr(kk, "idx", kk))) for kk in path)
        for path, _ in jax.tree_util.tree_flatten_with_path({"params": like})[0]
    ]
    new_leaves = [restored[k] for k in keys]
    params = jax.tree.unflatten(treedef, new_leaves)["params"]
    opt = None
    if any(k.startswith("opt/") for k in data.files):
        opt_flat: Dict[str, Any] = {}
        for k in data.files:
            if k.startswith("opt/"):
                parts = k.split("/")[1:]
                d = opt_flat
                for pp in parts[:-1]:
                    d = d.setdefault(pp, {})
                d[parts[-1]] = data[k]
        opt = opt_flat
    return params, opt, meta["step"]
